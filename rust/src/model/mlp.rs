//! Rust-native MLP denoiser forward pass.
//!
//! Bit-architecture mirror of python/compile/model.py operating on the
//! flat `weights_*.bin` buffer (layout: per layer, W row-major then b).
//! Two roles:
//! * parity oracle pinning the HLO execution path (tests compare both
//!   against golden.json forwards), and
//! * a fast in-process backend (`--backend native`) for experiments that
//!   need millions of cheap model calls.
//!
//! The batched forward is a GEMM pipeline (`math::gemm`) **compiled
//! into a dependency-counted tile graph**
//! ([`crate::runtime::pool::TileGraph`]): the batch is cut into row
//! blocks, each row block gets an f64→f32 pack node, then one packed
//! GEMM tile node per `(row block, column-panel range)` per layer —
//! where a layer-(l+1) tile of row block *i* depends only on the
//! layer-l tiles of row block *i* — and a final f32→f64 store node per
//! row block. There is **no barrier between layers**: row block 0 can
//! be in layer 3 while row block 1 is still packing, and on the shared
//! pool the layer-boundary gaps of one lane's round fill with another
//! lane's tiles. The serial path is the same compiler with a
//! degenerate 1×1 partition executed inline — one pipeline, two
//! schedules. Every layer's weight matrix is repacked **once at load**
//! into KC×NR column panels (`math::gemm::PackedB`), so the per-tile
//! kernel is the prepacked MR×NR register-tiled micro-kernel; the flat
//! row-major copy is kept only for the scalar reference path
//! ([`NativeMlp::forward_one_ref`] — the HLO parity oracle). Sinusoidal
//! time embeddings for the `k_steps` integer timesteps are precomputed
//! at load. Both paths reduce each output element in the same
//! ascending-input order; the GEMM path's SiLU uses the vectorizable
//! `math::gemm::exp_fast` (~1e-7 relative per layer) where the
//! reference calls libm `expf`, so parity holds to 1e-5 relative
//! rather than bitwise. Pool-size invariance of `denoise_batch` itself
//! *is* bitwise, for row sharding (`ParallelModel`), for the in-layer
//! 2-D GEMM tiling, and for the graph schedule: the graph's
//! dependency counters change only *when* a tile runs, never the tile
//! partition or any element's reduction order, and partitions only
//! regroup independent output elements.
//!
//! Which kernels run — and therefore which determinism tier the model
//! lands in ([`crate::math::isa`]) — is set by the
//! [`KernelPolicy`] passed to [`NativeMlp::from_flat_with`] /
//! [`NativeMlp::load_with`]: the ISA request is resolved against the
//! host **once at load** and every GEMM this model ever runs uses that
//! resolved ISA (so the tier's bit-stability-given-config holds by
//! construction), and the weight panels are packed at the policy's
//! precision. The plain `from_flat`/`load` entries use the default
//! policy (auto ISA, f32 panels); `ASD_GEMM_ISA=portable` restores the
//! seed's bit-exact behaviour globally. The scalar reference path
//! always reads the exact f32 bytes the artifacts shipped, whatever
//! the packed precision — it is the oracle the quantized tiers are
//! toleranced against.
//!
//! All math in f32 (matching the HLO) then widened to f64 at the edge.

use std::cell::RefCell;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::math::gemm::{gemm_packed_tile_on, Epilogue, PackedB, MR, NR};
use crate::math::isa::{DeterminismTier, Isa, KernelPolicy};
use crate::model::{DenoiseModel, VariantInfo};
use crate::runtime::pool::{self, TileGraph};
use crate::schedule::DdpmSchedule;

pub const TEMB_DIM: usize = 32;

/// Row-block height of the parallel graph partition: MR-aligned so
/// every tile runs the full-width micro-kernel except at the batch
/// tail. Two MR blocks per pack/store node keeps the node count (and
/// queue traffic) at half the finest possible grain.
const GRAPH_ROW_BLOCK: usize = 2 * MR;

/// Column width of one graph GEMM tile: eight NR panels, so a tile
/// amortizes its queue pop over a meaningful strip of packed panels
/// while small-M serve rounds still fan out over columns.
const GRAPH_PANEL_COLS: usize = 8 * NR;

/// Scratch arena for the batched GEMM forward. Buffers grow to the
/// high-water batch size and are reused, so the steady-state hot loop
/// performs zero heap allocations. `denoise_batch` uses a thread-local
/// workspace (one per pool worker — shards never contend); callers with
/// their own loop can pass one explicitly via
/// [`NativeMlp::denoise_batch_with`].
#[derive(Debug, Default)]
pub struct Workspace {
    /// packed B×in_dim input matrix `[x ‖ temb ‖ cond]`
    input: Vec<f32>,
    /// double-buffered activation planes, B×hidden each: non-output
    /// layer `l` writes `planes[l % 2]` and (for `l > 0`) reads
    /// `planes[(l - 1) % 2]`. Two planes suffice for the graph
    /// schedule because a layer-(l+2) tile of a row block can only run
    /// after that block's layer-(l+1) tiles — the sole readers of the
    /// plane it overwrites — have finished.
    planes: [Vec<f32>; 2],
    /// f32 output staging, B×d
    out32: Vec<f32>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    fn ensure(&mut self, n: usize, in_dim: usize, hidden: usize,
              d_out: usize) {
        grow(&mut self.input, n * in_dim);
        grow(&mut self.planes[0], n * hidden);
        grow(&mut self.planes[1], n * hidden);
        grow(&mut self.out32, n * d_out);
    }

    /// Bytes currently held by the scratch buffers (capacity, not
    /// round usage) — the high-water footprint a burst leaves behind.
    pub fn bytes(&self) -> usize {
        (self.input.capacity() + self.planes[0].capacity()
         + self.planes[1].capacity() + self.out32.capacity())
            * std::mem::size_of::<f32>()
    }

    /// Release the scratch buffers when they hold more than `cap`
    /// bytes (no-op otherwise). They regrow to the next batch's needs
    /// — call only between rounds, when the scratch contents are dead.
    pub fn shrink_to_cap(&mut self, cap: usize) {
        if self.bytes() <= cap {
            return;
        }
        let [p0, p1] = &mut self.planes;
        for v in [&mut self.input, p0, p1, &mut self.out32] {
            v.clear();
            v.shrink_to_fit();
        }
    }
}

fn grow(v: &mut Vec<f32>, len: usize) {
    if v.len() < len {
        v.resize(len, 0.0);
    }
}

thread_local! {
    /// Per-thread workspace backing the `DenoiseModel::denoise_batch`
    /// impl (the forward never re-enters itself on a thread, so the
    /// RefCell borrow is never contended).
    static WORKSPACE: RefCell<Workspace> = RefCell::new(Workspace::new());
}

#[derive(Debug)]
pub struct NativeMlp {
    pub d: usize,
    pub cond_dim: usize,
    pub k_steps: usize,
    layers: Vec<Layer>,
    /// hidden width (n_out of the input layer; all residual blocks are
    /// hidden×hidden — validated at load)
    hidden: usize,
    schedule: DdpmSchedule,
    /// precomputed sinusoidal frequencies
    freqs: Vec<f32>,
    /// sinusoidal embeddings for integer timesteps, `(k_steps+1) ×
    /// TEMB_DIM` row-major: a trajectory only ever visits `k_steps`
    /// distinct values, so verify batches never recompute sin/cos
    temb_cache: Vec<f32>,
    /// requested kernel policy (ISA request + panel precision)
    policy: KernelPolicy,
    /// ISA resolved once at load — every GEMM this model runs uses it,
    /// which is what makes the reproducible-given-config tier hold
    isa: Isa,
}

#[derive(Debug)]
struct Layer {
    n_in: usize,
    n_out: usize,
    /// flat row-major (n_in, n_out) copy — kept only for the scalar
    /// reference path (`forward_one_ref` / `denoise_batch_ref`, the
    /// HLO parity oracle). Deliberate ~2x weight memory at load: the
    /// oracle must read the exact bytes the artifacts shipped, and
    /// reconstructing rows from the packed panels would put a strided
    /// unpack (or per-call scratch) inside the reference path the
    /// parity tests are supposed to keep dead simple.
    w: Vec<f32>,
    /// the same weights repacked once at load into KC×NR column panels
    /// — what every GEMM-pipeline round actually reads
    wp: PackedB,
    b: Vec<f32>,
}

impl NativeMlp {
    pub fn load(info: &VariantInfo, artifacts_dir: &Path) -> Result<Arc<NativeMlp>> {
        Self::load_with(info, artifacts_dir, KernelPolicy::default())
    }

    /// [`load`](Self::load) with an explicit kernel policy (GEMM ISA
    /// request + packed-panel precision).
    pub fn load_with(info: &VariantInfo, artifacts_dir: &Path,
                     policy: KernelPolicy) -> Result<Arc<NativeMlp>> {
        let path = artifacts_dir.join(&info.weights_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("weights file not a multiple of 4 bytes");
        }
        let flat: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Self::from_flat_with(info, &flat, policy)
    }

    pub fn from_flat(info: &VariantInfo, flat: &[f32]) -> Result<Arc<NativeMlp>> {
        Self::from_flat_with(info, flat, KernelPolicy::default())
    }

    /// [`from_flat`](Self::from_flat) with an explicit kernel policy:
    /// weight panels are packed at `policy.precision` and the ISA
    /// request is resolved against the host here, once.
    pub fn from_flat_with(info: &VariantInfo, flat: &[f32],
                          policy: KernelPolicy) -> Result<Arc<NativeMlp>> {
        let mut layers = Vec::new();
        let mut off = 0usize;
        for &(n_in, n_out) in &info.weights_layout {
            let w_end = off + n_in * n_out;
            let b_end = w_end + n_out;
            if b_end > flat.len() {
                bail!("weights file too short: need {b_end}, have {}", flat.len());
            }
            let w = flat[off..w_end].to_vec();
            layers.push(Layer {
                n_in,
                n_out,
                wp: PackedB::pack_as(n_in, n_out, &w, policy.precision),
                w,
                b: flat[w_end..b_end].to_vec(),
            });
            off = b_end;
        }
        if off != flat.len() {
            bail!("weights file has {} trailing floats", flat.len() - off);
        }
        // shape validation: the forward assumes input layer -> zero or
        // more hidden×hidden residual blocks -> output layer (the seed
        // trusted this silently via debug_asserts)
        ensure!(layers.len() >= 2,
                "MLP needs >= 2 layers (input + output), got {}",
                layers.len());
        let in_dim = info.d + TEMB_DIM + info.cond_dim;
        ensure!(layers[0].n_in == in_dim,
                "input layer expects n_in={} (d+temb+cond), got {}",
                in_dim, layers[0].n_in);
        let hidden = layers[0].n_out;
        for (i, l) in layers[1..layers.len() - 1].iter().enumerate() {
            ensure!(l.n_in == hidden && l.n_out == hidden,
                    "residual block {i} must be {hidden}x{hidden}, \
                     got {}x{}", l.n_in, l.n_out);
        }
        let last = layers.last().unwrap();
        ensure!(last.n_in == hidden && last.n_out == info.d,
                "output layer must be {hidden}x{}, got {}x{}",
                info.d, last.n_in, last.n_out);
        let half = TEMB_DIM / 2;
        let freqs: Vec<f32> = (0..half)
            .map(|j| (-(10000f32.ln()) * j as f32 / (half - 1) as f32).exp())
            .collect();
        let mut temb_cache = vec![0.0f32; (info.k_steps + 1) * TEMB_DIM];
        for t in 0..=info.k_steps {
            embed_time_raw(&freqs, info.k_steps, t as f32,
                           &mut temb_cache[t * TEMB_DIM..(t + 1) * TEMB_DIM]);
        }
        Ok(Arc::new(NativeMlp {
            d: info.d,
            cond_dim: info.cond_dim,
            k_steps: info.k_steps,
            layers,
            hidden,
            schedule: info.schedule(),
            freqs,
            temb_cache,
            policy,
            isa: policy.resolve_isa(),
        }))
    }

    /// Input layer width: d + TEMB_DIM + cond_dim.
    pub fn in_dim(&self) -> usize {
        self.d + TEMB_DIM + self.cond_dim
    }

    /// The kernel policy this model was loaded with.
    pub fn kernel_policy(&self) -> KernelPolicy {
        self.policy
    }

    /// The ISA the policy resolved to at load (fixed for the model's
    /// lifetime).
    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// The determinism tier this model's forward passes ship under.
    pub fn determinism_tier(&self) -> DeterminismTier {
        self.policy.tier()
    }

    fn embed_time(&self, t: f32, out: &mut [f32]) {
        embed_time_raw(&self.freqs, self.k_steps, t, out);
    }

    /// Time-embedding row for `t`: cache hit for the integer timesteps
    /// every sampler actually visits, fresh sin/cos otherwise
    /// (bit-identical either way — the cache was filled by the same
    /// function).
    fn fill_temb(&self, t: f64, out: &mut [f32]) {
        let ti = t as usize;
        if t >= 0.0 && t.fract() == 0.0 && ti <= self.k_steps {
            out.copy_from_slice(
                &self.temb_cache[ti * TEMB_DIM..(ti + 1) * TEMB_DIM]);
        } else {
            self.embed_time(t as f32, out);
        }
    }

    /// Scalar single-row forward — the pre-GEMM reference path, kept as
    /// the parity oracle the batched pipeline is tested against.
    /// Input (in_dim), writes x0hat (d).
    pub fn forward_one_ref(&self, input: &[f32], out: &mut [f32]) {
        debug_assert_eq!(input.len(), self.in_dim());
        // first layer + silu
        let l0 = &self.layers[0];
        let mut h = vec![0f32; l0.n_out];
        linear_silu(input, l0, &mut h);
        // residual hidden blocks
        let mut tmp = vec![0f32; l0.n_out];
        for layer in &self.layers[1..self.layers.len() - 1] {
            linear_silu(&h, layer, &mut tmp);
            for i in 0..h.len() {
                h[i] += tmp[i];
            }
        }
        // output layer, no activation
        let lo = self.layers.last().unwrap();
        debug_assert_eq!(out.len(), lo.n_out);
        linear(&h, lo, out);
    }

    /// Row-at-a-time reference `denoise_batch` (scalar `linear()` path,
    /// libm SiLU, fresh time embeddings, per-call scratch): the oracle
    /// for GEMM parity tests (1e-5 relative) and the bench baseline.
    pub fn denoise_batch_ref(&self, ys: &[f64], ts: &[f64], cond: &[f64],
                             n: usize, out: &mut [f64]) -> Result<()> {
        let (d, c) = (self.d, self.cond_dim);
        ensure!(ys.len() == n * d && ts.len() == n && cond.len() == n * c
                    && out.len() >= n * d,
                "denoise_batch_ref shape mismatch: n={n} d={d} c={c}");
        let mut input = vec![0f32; self.in_dim()];
        let mut x0 = vec![0f32; d];
        for r in 0..n {
            for i in 0..d {
                input[i] = ys[r * d + i] as f32;
            }
            let (temb, rest) = input[d..].split_at_mut(TEMB_DIM);
            self.embed_time(ts[r] as f32, temb);
            for i in 0..c {
                rest[i] = cond[r * c + i] as f32;
            }
            self.forward_one_ref(&input, &mut x0);
            for i in 0..d {
                out[r * d + i] = x0[i] as f64;
            }
        }
        Ok(())
    }

    /// The GEMM pipeline with a caller-owned workspace: the graph
    /// compiler's degenerate 1×1 partition (one pack node, one tile
    /// per layer, one store node) executed inline on the calling
    /// thread — exactly the old serial per-layer loop, expressed as
    /// the same compiled pipeline the parallel paths run.
    pub fn denoise_batch_with(&self, ys: &[f64], ts: &[f64], cond: &[f64],
                              n: usize, out: &mut [f64], ws: &mut Workspace)
                              -> Result<()> {
        self.denoise_batch_tiled(ys, ts, cond, n, out, ws, 1)
    }

    /// [`denoise_batch_with`](Self::denoise_batch_with) compiled for
    /// `tile_shards > 1` into the full row-block × column-panel tile
    /// graph and executed barrier-free on the global worker pool
    /// ([`pool::ThreadPool::run_graph`], caller helping). Small
    /// batches — fused serving rounds — parallelize over the weight
    /// matrix's column panels even when they have too few rows to
    /// row-shard, and no layer ever fork/joins the pool.
    /// Bit-identical to the serial pipeline for every `tile_shards`
    /// and steal schedule (tiles never split an element's reduction,
    /// and the kernel is fixed per model, so this holds in every
    /// determinism tier).
    pub fn denoise_batch_tiled(&self, ys: &[f64], ts: &[f64], cond: &[f64],
                               n: usize, out: &mut [f64],
                               ws: &mut Workspace, tile_shards: usize)
                               -> Result<()> {
        let graph =
            self.compile_graph(ys, ts, cond, n, out, ws, tile_shards > 1)?;
        if tile_shards > 1 {
            pool::global().run_graph(graph);
        } else {
            graph.run_inline();
        }
        Ok(())
    }

    /// Compile one fused forward over rows `0..n` into a
    /// dependency-counted [`TileGraph`]. Node kinds per row block:
    /// one f64→f32 **pack** node (`[x ‖ temb ‖ cond]`, cached integer
    /// time embeddings), per layer one packed-GEMM **tile** node per
    /// column-panel range — each layer-(l+1) tile depending on all of
    /// *this row block's* layer-l tiles and nothing else — and one
    /// f32→f64 **store** node. `parallel` picks the partition:
    /// `false` is the degenerate 1 row block × full-width panels
    /// (serial schedule), `true` the [`GRAPH_ROW_BLOCK`] ×
    /// [`GRAPH_PANEL_COLS`] grid. The partition is a pure function of
    /// the shapes — never of the pool size or host ISA — and output
    /// bits are independent of it anyway (each element's reduction
    /// runs whole inside one tile, ascending-k).
    ///
    /// The returned graph holds raw pointers into `ys`/`ts`/`cond`/
    /// `out`/`ws` and `self`; the caller must keep all of them alive
    /// and untouched until the graph has fully executed (the
    /// synchronous entries block; the lane path keeps its arena and
    /// model untouched until the round group drains — the same
    /// contract boxed round closures already had).
    fn compile_graph(&self, ys: &[f64], ts: &[f64], cond: &[f64],
                     n: usize, out: &mut [f64], ws: &mut Workspace,
                     parallel: bool) -> Result<TileGraph> {
        let (d, c) = (self.d, self.cond_dim);
        let in_dim = self.in_dim();
        let hidden = self.hidden;
        ensure!(ys.len() == n * d && ts.len() == n && cond.len() == n * c
                    && out.len() >= n * d,
                "denoise_batch shape mismatch: n={n} d={d} c={c} ys={} \
                 ts={} cond={} out={}",
                ys.len(), ts.len(), cond.len(), out.len());
        let mut graph = TileGraph::new();
        if n == 0 {
            return Ok(graph);
        }
        ws.ensure(n, in_dim, hidden, d);
        let (row_block, panel_cols) = if parallel {
            (GRAPH_ROW_BLOCK, GRAPH_PANEL_COLS)
        } else {
            (n, usize::MAX)
        };
        let p = RoundPtrs {
            model: self,
            ys: ys.as_ptr(),
            ts: ts.as_ptr(),
            cond: cond.as_ptr(),
            out: out.as_mut_ptr(),
            input: ws.input.as_mut_ptr(),
            planes: [ws.planes[0].as_mut_ptr(), ws.planes[1].as_mut_ptr()],
            out32: ws.out32.as_mut_ptr(),
        };
        let n_layers = self.layers.len();
        let mut r0 = 0usize;
        while r0 < n {
            let r1 = (r0 + row_block).min(n);
            let rows = r1 - r0;
            // pack node: this row block's [x | temb | cond] rows
            let pack = graph.add_node(&[], move || {
                // SAFETY: the pack node owns rows r0..r1 of the input
                // matrix exclusively (row blocks are disjoint), and the
                // ys/ts/cond sources are frozen for the graph's life.
                unsafe { p.pack_rows(r0, rows) }
            });
            let mut prev = vec![pack];
            for li in 0..n_layers {
                let layer = &self.layers[li];
                let (k, n_out) = (layer.n_in, layer.n_out);
                // SAFETY: pointer arithmetic only — the buffers were
                // just ensured to hold n rows of every plane.
                let (a, residual, cbase) = unsafe {
                    if li == 0 {
                        (p.input.add(r0 * in_dim) as *const f32, None,
                         p.planes[0].add(r0 * hidden))
                    } else if li + 1 == n_layers {
                        (p.planes[(li - 1) % 2].add(r0 * hidden)
                             as *const f32,
                         None, p.out32.add(r0 * d))
                    } else {
                        let src = p.planes[(li - 1) % 2].add(r0 * hidden)
                            as *const f32;
                        (src, Some(src), p.planes[li % 2].add(r0 * hidden))
                    }
                };
                let model = p.model;
                let mut tiles =
                    Vec::with_capacity(n_out.div_ceil(panel_cols.max(1)));
                let mut j0 = 0usize;
                while j0 < n_out {
                    let j1 = j0.saturating_add(panel_cols).min(n_out);
                    let t = GemmTile {
                        model, layer: li, rows, j0, j1, k, a, residual,
                        c: cbase,
                    };
                    // depends on ALL of this row block's previous-stage
                    // nodes (pack, or every layer-(l-1) tile)
                    tiles.push(graph.add_node(&prev, move || {
                        // SAFETY: dependency edges freeze the A and
                        // residual rows and make the C columns
                        // exclusive; see GemmTile::run.
                        unsafe { t.run() }
                    }));
                    j0 = j1;
                }
                prev = tiles;
            }
            // store node: widen this row block's f32 staging to f64
            graph.add_node(&prev, move || {
                // SAFETY: all last-layer tiles of this row block have
                // finished (deps); rows r0..r1 of out are exclusive.
                unsafe { p.store_rows(r0, rows) }
            });
            r0 = r1;
        }
        Ok(graph)
    }
}

/// Raw-pointer bundle the graph nodes capture: the model plus the
/// round's input/output/scratch base pointers. Copied into every node;
/// `Send + Sync` because node tasks hop threads. Soundness is the
/// graph dependency rule (see [`NativeMlp::compile_graph`]) plus the
/// caller's keep-alive contract.
#[derive(Clone, Copy)]
struct RoundPtrs {
    model: *const NativeMlp,
    ys: *const f64,
    ts: *const f64,
    cond: *const f64,
    out: *mut f64,
    input: *mut f32,
    planes: [*mut f32; 2],
    out32: *mut f32,
}

unsafe impl Send for RoundPtrs {}
unsafe impl Sync for RoundPtrs {}

impl RoundPtrs {
    /// Pack rows `r0..r0+rows` of the round's input matrix.
    ///
    /// SAFETY: caller (the graph schedule) guarantees exclusive
    /// ownership of those input-matrix rows and frozen sources.
    unsafe fn pack_rows(&self, r0: usize, rows: usize) {
        let model = &*self.model;
        let (d, c) = (model.d, model.cond_dim);
        let in_dim = model.in_dim();
        let input = std::slice::from_raw_parts_mut(
            self.input.add(r0 * in_dim), rows * in_dim);
        let ys = std::slice::from_raw_parts(self.ys.add(r0 * d), rows * d);
        let ts = std::slice::from_raw_parts(self.ts.add(r0), rows);
        let cond =
            std::slice::from_raw_parts(self.cond.add(r0 * c), rows * c);
        for r in 0..rows {
            let row = &mut input[r * in_dim..(r + 1) * in_dim];
            for i in 0..d {
                row[i] = ys[r * d + i] as f32;
            }
            let (temb, rest) = row[d..].split_at_mut(TEMB_DIM);
            model.fill_temb(ts[r], temb);
            for i in 0..c {
                rest[i] = cond[r * c + i] as f32;
            }
        }
    }

    /// Widen rows `r0..r0+rows` of the f32 staging into the f64 out.
    ///
    /// SAFETY: caller guarantees those staging rows are final and the
    /// out rows exclusive.
    unsafe fn store_rows(&self, r0: usize, rows: usize) {
        let model = &*self.model;
        let d = model.d;
        let src = std::slice::from_raw_parts(self.out32.add(r0 * d),
                                             rows * d);
        let dst = std::slice::from_raw_parts_mut(self.out.add(r0 * d),
                                                 rows * d);
        for (o, &v) in dst.iter_mut().zip(src) {
            *o = v as f64;
        }
    }
}

/// One packed-GEMM tile node: rows of one row block × packed column
/// panels `[j0, j1)` of one layer, full bias→accumulate→epilogue.
/// All pointer arithmetic happens at compile time; the node just runs.
#[derive(Clone, Copy)]
struct GemmTile {
    model: *const NativeMlp,
    layer: usize,
    rows: usize,
    j0: usize,
    j1: usize,
    k: usize,
    /// row 0 of this row block in the layer's input (lda = k)
    a: *const f32,
    /// residual rows (lda = n_out), the fused skip connection
    residual: Option<*const f32>,
    /// row 0, column 0 of this row block in the layer's output
    c: *mut f32,
}

unsafe impl Send for GemmTile {}
unsafe impl Sync for GemmTile {}

impl GemmTile {
    /// SAFETY: the graph dependency rule guarantees the A/residual
    /// rows are fully written and no longer mutated, and columns
    /// `[j0, j1)` of the C rows are exclusively this tile's. All GEMMs
    /// run on the ISA resolved at model load — never re-resolved per
    /// tile — so outputs are bit-stable whatever the pool does.
    unsafe fn run(self) {
        let model = &*self.model;
        let l = &model.layers[self.layer];
        gemm_packed_tile_on(model.isa, self.rows, self.j0, self.j1,
                            self.k, self.a, &l.wp, Some(&l.b),
                            if self.layer + 1 == model.layers.len() {
                                Epilogue::Linear
                            } else {
                                Epilogue::Silu
                            },
                            self.residual, self.c);
    }
}

/// Time embedding against explicit frequencies (callable before the
/// struct exists, so load can fill the cache with the same bits the
/// fallback path produces).
fn embed_time_raw(freqs: &[f32], k_steps: usize, t: f32, out: &mut [f32]) {
    let half = TEMB_DIM / 2;
    let scaled = t / k_steps as f32 * 1000.0;
    for j in 0..half {
        let ang = scaled * freqs[j];
        out[j] = ang.sin();
        out[half + j] = ang.cos();
    }
}

/// Scalar reference linear layer. The seed skipped `xi == 0.0` inputs;
/// that "fast path" blocked vectorization and changed NaN/Inf
/// propagation (0.0 * NaN must be NaN, not silently dropped), so both
/// paths now always accumulate — see the NaN regression test below.
#[inline]
fn linear(x: &[f32], l: &Layer, out: &mut [f32]) {
    debug_assert_eq!(x.len(), l.n_in);
    out.copy_from_slice(&l.b);
    for (i, &xi) in x.iter().enumerate() {
        let row = &l.w[i * l.n_out..(i + 1) * l.n_out];
        for (o, &wv) in out.iter_mut().zip(row) {
            *o += xi * wv;
        }
    }
}

#[inline]
fn linear_silu(x: &[f32], l: &Layer, out: &mut [f32]) {
    linear(x, l, out);
    for o in out.iter_mut() {
        *o = *o / (1.0 + (-*o).exp());
    }
}

impl DenoiseModel for NativeMlp {
    fn dim(&self) -> usize {
        self.d
    }

    fn cond_dim(&self) -> usize {
        self.cond_dim
    }

    fn k_steps(&self) -> usize {
        self.k_steps
    }

    fn schedule(&self) -> &DdpmSchedule {
        &self.schedule
    }

    fn denoise_batch(&self, ys: &[f64], ts: &[f64], cond: &[f64], n: usize,
                     out: &mut [f64]) -> Result<()> {
        WORKSPACE.with(|ws| {
            self.denoise_batch_with(ys, ts, cond, n, out, &mut ws.borrow_mut())
        })
    }

    /// Arena rounds run the GEMM pipeline against the *arena's*
    /// workspace: the whole round's f64→f32 conversion packs once into
    /// the per-lane buffers, which persist across rounds/ticks (the
    /// thread-local workspace stays the target for pool-worker
    /// sub-calls, where each worker needs its own scratch).
    /// Bit-identical to `denoise_batch` — the workspace is pure
    /// scratch, and the serial schedule here runs the identical
    /// compiled graph [`compile_round`](DenoiseModel::compile_round)
    /// hands the pool.
    fn denoise_round(&self, arena: &mut crate::sampler::RoundArena)
                     -> Result<()> {
        let (ys, ts, cond, n, out, ws) = arena.round_io_ws();
        self.compile_graph(ys, ts, cond, n, out, ws, false)?
            .run_inline();
        Ok(())
    }

    /// The barrier-free round form: the full row-block × column-panel
    /// tile graph over the arena's buffers, for the caller to execute
    /// on the pool. The graph captures raw pointers into the arena (and
    /// `self`) — the standing lane contract (arena untouched until the
    /// round's `RoundGroup` completion arrives) is exactly its
    /// keep-alive requirement.
    fn compile_round(&self, arena: &mut crate::sampler::RoundArena)
                     -> Result<Option<TileGraph>> {
        let (ys, ts, cond, n, out, ws) = arena.round_io_ws();
        Ok(Some(self.compile_graph(ys, ts, cond, n, out, ws, true)?))
    }

    /// Graph rounds never fork/join the pool between layers.
    fn round_barriers(&self, _n: usize) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `layers` counts the non-output layers, as the seed's helper did
    /// (layers = 1 + residual blocks).
    fn toy_info(d: usize, cond: usize, hidden: usize, layers: usize) -> VariantInfo {
        VariantInfo::toy("toy", d, cond, hidden, layers - 1, 10)
    }

    fn flat_len(info: &VariantInfo) -> usize {
        info.weights_len()
    }

    fn pseudo_weights(n_w: usize) -> Vec<f32> {
        (0..n_w).map(|i| ((i * 37 % 101) as f32 / 101.0) - 0.5).collect()
    }

    #[test]
    fn zero_weights_zero_output() {
        let info = toy_info(2, 0, 4, 2);
        let flat = vec![0f32; flat_len(&info)];
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        let mut out = vec![9.0; 2];
        mlp.denoise_one(&[1.0, 2.0], 5, &[], &mut out).unwrap();
        assert_eq!(out, vec![0.0, 0.0]);
    }

    #[test]
    fn batch_equals_loop() {
        let info = toy_info(3, 2, 8, 2);
        let flat = pseudo_weights(flat_len(&info));
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        let ys = [0.1, -0.2, 0.3, 0.5, 0.6, -0.7];
        let ts = [3.0, 7.0];
        let cond = [1.0, 0.0, 0.0, 1.0];
        let mut batch = vec![0.0; 6];
        mlp.denoise_batch(&ys, &ts, &cond, 2, &mut batch).unwrap();
        for r in 0..2 {
            let mut one = vec![0.0; 3];
            mlp.denoise_batch(&ys[r * 3..(r + 1) * 3], &ts[r..r + 1],
                              &cond[r * 2..(r + 1) * 2], 1, &mut one)
                .unwrap();
            assert_eq!(&batch[r * 3..(r + 1) * 3], &one[..]);
        }
    }

    #[test]
    fn gemm_path_matches_scalar_ref() {
        // odd batch sizes straddle the GEMM row-tile; deep-ish net
        // exercises the fused residual epilogue. Parity is 1e-5
        // relative (the GEMM SiLU uses exp_fast, the ref libm expf).
        let info = toy_info(3, 2, 8, 3);
        let flat = pseudo_weights(flat_len(&info));
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        for n in [0usize, 1, 2, 3, 4, 5, 9, 64] {
            let ys: Vec<f64> =
                (0..n * 3).map(|i| (i as f64 * 0.41).sin()).collect();
            let ts: Vec<f64> = (0..n).map(|r| (1 + r % 10) as f64).collect();
            let cond: Vec<f64> =
                (0..n * 2).map(|i| (i as f64 * 0.17).cos()).collect();
            let mut want = vec![0.0; n * 3];
            mlp.denoise_batch_ref(&ys, &ts, &cond, n, &mut want).unwrap();
            let mut got = vec![0.0; n * 3];
            mlp.denoise_batch(&ys, &ts, &cond, n, &mut got).unwrap();
            for i in 0..n * 3 {
                let tol = 1e-5 * want[i].abs().max(1.0);
                assert!((want[i] - got[i]).abs() <= tol,
                        "n={n} i={i}: ref {} vs gemm {}", want[i], got[i]);
            }
        }
    }

    #[test]
    fn gemm_batch_is_bitwise_stable_across_batch_shapes() {
        // the GEMM path itself must be deterministic in the batch
        // shape: a row's result cannot depend on its neighbours (this
        // is what makes pool sharding bit-transparent)
        let info = toy_info(3, 0, 8, 2);
        let flat = pseudo_weights(flat_len(&info));
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        let n = 11usize;
        let ys: Vec<f64> =
            (0..n * 3).map(|i| (i as f64 * 0.29).sin()).collect();
        let ts: Vec<f64> = (0..n).map(|r| (1 + r % 10) as f64).collect();
        let mut full = vec![0.0; n * 3];
        mlp.denoise_batch(&ys, &ts, &[], n, &mut full).unwrap();
        for r in 0..n {
            let mut one = vec![0.0; 3];
            mlp.denoise_batch(&ys[r * 3..(r + 1) * 3], &ts[r..r + 1], &[],
                              1, &mut one).unwrap();
            for i in 0..3 {
                assert_eq!(full[r * 3 + i].to_bits(), one[i].to_bits(),
                           "row {r} dim {i}");
            }
        }
    }

    #[test]
    fn tiled_pipeline_is_bitwise_invariant_in_tile_shards() {
        // the 2-D GEMM tiling inside the pipeline must never change a
        // bit — this is what lets ParallelModel hand small-M serving
        // rounds to the backend's own tiling
        let info = toy_info(3, 2, 16, 3);
        let flat = pseudo_weights(flat_len(&info));
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        let mut ws = Workspace::new();
        for n in [1usize, 2, 4, 5, 11] {
            let ys: Vec<f64> =
                (0..n * 3).map(|i| (i as f64 * 0.19).sin()).collect();
            let ts: Vec<f64> = (0..n).map(|r| (1 + r % 10) as f64).collect();
            let cond: Vec<f64> =
                (0..n * 2).map(|i| (i as f64 * 0.07).cos()).collect();
            let mut want = vec![0.0; n * 3];
            mlp.denoise_batch_with(&ys, &ts, &cond, n, &mut want, &mut ws)
                .unwrap();
            for shards in [2usize, 8] {
                let mut got = vec![0.0; n * 3];
                mlp.denoise_batch_tiled(&ys, &ts, &cond, n, &mut got,
                                        &mut ws, shards)
                    .unwrap();
                for i in 0..n * 3 {
                    assert_eq!(want[i].to_bits(), got[i].to_bits(),
                               "n={n} shards={shards} i={i}");
                }
            }
        }
    }

    #[test]
    fn workspace_bytes_and_shrink_to_cap() {
        let mut ws = Workspace::new();
        assert_eq!(ws.bytes(), 0);
        ws.ensure(64, 10, 32, 4);
        let grown = ws.bytes();
        assert!(grown >= 64 * (10 + 32 + 32 + 4) * 4);
        // under the cap: untouched
        ws.shrink_to_cap(grown);
        assert_eq!(ws.bytes(), grown);
        // over the cap: released entirely, then regrows on demand
        ws.shrink_to_cap(grown - 1);
        assert_eq!(ws.bytes(), 0);
        ws.ensure(8, 10, 32, 4);
        assert!(ws.bytes() > 0);
    }

    #[test]
    fn caller_workspace_reuse_matches_thread_local() {
        let info = toy_info(2, 0, 6, 2);
        let flat = pseudo_weights(flat_len(&info));
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        let mut ws = Workspace::new();
        // shrinking then growing batch sizes reuse the same arena
        for n in [8usize, 1, 5, 8] {
            let ys: Vec<f64> = (0..n * 2).map(|i| i as f64 * 0.3).collect();
            let ts: Vec<f64> = (0..n).map(|r| (1 + r % 10) as f64).collect();
            let mut a = vec![0.0; n * 2];
            mlp.denoise_batch_with(&ys, &ts, &[], n, &mut a, &mut ws)
                .unwrap();
            let mut b = vec![0.0; n * 2];
            mlp.denoise_batch(&ys, &ts, &[], n, &mut b).unwrap();
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn arena_round_matches_batch_bitwise() {
        // the per-lane arena workspace path must produce the exact bits
        // of the thread-local denoise_batch path (workspace is scratch)
        use crate::model::DenoiseModel;
        use crate::sampler::RoundArena;
        let info = toy_info(3, 2, 8, 3);
        let flat = pseudo_weights(flat_len(&info));
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        let mut arena = RoundArena::new(3, 2);
        for n in [5usize, 1, 9] {
            let ys: Vec<f64> =
                (0..n * 3).map(|i| (i as f64 * 0.23).sin()).collect();
            let ts: Vec<f64> = (0..n).map(|r| (1 + r % 10) as f64).collect();
            let cond: Vec<f64> =
                (0..n * 2).map(|i| (i as f64 * 0.11).cos()).collect();
            let mut want = vec![0.0; n * 3];
            mlp.denoise_batch(&ys, &ts, &cond, n, &mut want).unwrap();
            arena.begin_round();
            let (span, rows) = arena.reserve(n);
            rows.ys.copy_from_slice(&ys);
            rows.ts.copy_from_slice(&ts);
            rows.cond.copy_from_slice(&cond);
            mlp.denoise_round(&mut arena).unwrap();
            let got = arena.out_rows(span);
            for i in 0..n * 3 {
                assert_eq!(want[i].to_bits(), got[i].to_bits(),
                           "n={n} i={i}");
            }
        }
    }

    #[test]
    fn compiled_round_graph_matches_inline_round_bitwise() {
        // the pool-executed tile graph (compile_round) and the inline
        // serial schedule (denoise_round) are the same compiled
        // pipeline — outputs must match bit for bit, whatever the
        // steal schedule does
        use crate::model::DenoiseModel;
        use crate::sampler::RoundArena;
        let info = toy_info(3, 2, 16, 3);
        let flat = pseudo_weights(flat_len(&info));
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        for n in [1usize, 4, 9, 21] {
            let ys: Vec<f64> =
                (0..n * 3).map(|i| (i as f64 * 0.37).sin()).collect();
            let ts: Vec<f64> = (0..n).map(|r| (1 + r % 10) as f64).collect();
            let cond: Vec<f64> =
                (0..n * 2).map(|i| (i as f64 * 0.09).cos()).collect();
            let fill = |arena: &mut RoundArena| {
                arena.begin_round();
                let (span, rows) = arena.reserve(n);
                rows.ys.copy_from_slice(&ys);
                rows.ts.copy_from_slice(&ts);
                rows.cond.copy_from_slice(&cond);
                span
            };
            let mut arena = RoundArena::new(3, 2);
            let span = fill(&mut arena);
            mlp.denoise_round(&mut arena).unwrap();
            let want: Vec<u64> =
                arena.out_rows(span).iter().map(|v| v.to_bits()).collect();
            for _ in 0..3 {
                let span = fill(&mut arena);
                let graph = mlp.compile_round(&mut arena).unwrap().unwrap();
                assert!(!graph.is_empty());
                pool::global().run_graph(graph);
                let got: Vec<u64> = arena.out_rows(span).iter()
                    .map(|v| v.to_bits()).collect();
                assert_eq!(want, got, "n={n}");
            }
        }
    }

    #[test]
    fn temb_cache_matches_fresh_embedding() {
        let info = toy_info(2, 0, 4, 2);
        let flat = vec![0f32; flat_len(&info)];
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        let mut fresh = vec![0f32; TEMB_DIM];
        let mut cached = vec![0f32; TEMB_DIM];
        for t in 0..=10usize {
            mlp.embed_time(t as f32, &mut fresh);
            mlp.fill_temb(t as f64, &mut cached);
            assert_eq!(fresh.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                       cached.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                       "t={t}");
        }
        // non-integer / out-of-range t falls back to fresh sin/cos
        mlp.embed_time(3.5, &mut fresh);
        mlp.fill_temb(3.5, &mut cached);
        assert_eq!(fresh, cached);
        mlp.embed_time(99.0, &mut fresh);
        mlp.fill_temb(99.0, &mut cached);
        assert_eq!(fresh, cached);
    }

    #[test]
    fn nan_weights_propagate_even_for_zero_inputs() {
        // regression for the removed `xi == 0.0` skip in linear(): a NaN
        // weight hit by a zero input must poison the output (0 * NaN =
        // NaN), matching GEMM/HLO semantics — the old fast path
        // silently dropped it.
        let info = toy_info(2, 0, 4, 2);
        let mut flat = vec![0f32; flat_len(&info)];
        flat[0] = f32::NAN; // W0[0][0]: first input coordinate, first unit
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        let mut out = vec![0.0; 2];
        // input x = (0, 0): the NaN-weighted coordinate is exactly 0.0
        mlp.denoise_one(&[0.0, 0.0], 5, &[], &mut out).unwrap();
        assert!(out.iter().all(|v| v.is_nan()),
                "NaN was dropped: {out:?}");
        // and the scalar ref path agrees
        let mut out_ref = vec![0.0; 2];
        mlp.denoise_batch_ref(&[0.0, 0.0], &[5.0], &[], 1, &mut out_ref)
            .unwrap();
        assert!(out_ref.iter().all(|v| v.is_nan()));
    }

    #[test]
    fn wrong_length_weights_rejected() {
        let info = toy_info(2, 0, 4, 1);
        assert!(NativeMlp::from_flat(&info, &vec![0f32; 3]).is_err());
        let too_many = vec![0f32; flat_len(&info) + 1];
        assert!(NativeMlp::from_flat(&info, &too_many).is_err());
    }

    #[test]
    fn inconsistent_layer_shapes_rejected() {
        // residual block whose width doesn't match the hidden state
        let mut info = toy_info(2, 0, 4, 2);
        info.weights_layout[1] = (4, 5);
        info.weights_layout[2] = (5, 2);
        let n_w = flat_len(&info);
        assert!(NativeMlp::from_flat(&info, &vec![0f32; n_w]).is_err());
        // output layer that doesn't produce d columns
        let mut info = toy_info(2, 0, 4, 1);
        let last = info.weights_layout.len() - 1;
        info.weights_layout[last] = (4, 3);
        let n_w = flat_len(&info);
        assert!(NativeMlp::from_flat(&info, &vec![0f32; n_w]).is_err());
    }

    #[test]
    fn time_embedding_range_and_distinct() {
        let info = toy_info(2, 0, 4, 1);
        let flat = vec![0f32; flat_len(&info)];
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        let mut e1 = vec![0f32; TEMB_DIM];
        let mut e2 = vec![0f32; TEMB_DIM];
        mlp.embed_time(1.0, &mut e1);
        mlp.embed_time(9.0, &mut e2);
        assert!(e1.iter().all(|v| v.abs() <= 1.0));
        let diff: f32 = e1.iter().zip(&e2).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 0.1);
    }

    #[test]
    fn quantized_policies_track_scalar_ref_within_tier_bound() {
        use crate::math::isa::{IsaRequest, Precision};
        let info = toy_info(3, 2, 16, 3);
        let flat = pseudo_weights(flat_len(&info));
        let n = 7usize;
        let ys: Vec<f64> =
            (0..n * 3).map(|i| (i as f64 * 0.31).sin()).collect();
        let ts: Vec<f64> = (0..n).map(|r| (1 + r % 10) as f64).collect();
        let cond: Vec<f64> =
            (0..n * 2).map(|i| (i as f64 * 0.13).cos()).collect();
        for precision in [Precision::F16, Precision::Int8] {
            let policy = KernelPolicy { isa: IsaRequest::Auto, precision };
            let mlp = NativeMlp::from_flat_with(&info, &flat, policy).unwrap();
            assert_eq!(mlp.determinism_tier(),
                       DeterminismTier::QuantizedWithErrorBound);
            assert_eq!(mlp.kernel_policy().precision, precision);
            // the scalar ref path reads the exact f32 bytes, so even on
            // a quantized model it is the f32 oracle
            let mut want = vec![0.0; n * 3];
            mlp.denoise_batch_ref(&ys, &ts, &cond, n, &mut want).unwrap();
            let mut got = vec![0.0; n * 3];
            mlp.denoise_batch(&ys, &ts, &cond, n, &mut got).unwrap();
            let tol = policy.denoise_rel_tolerance();
            for i in 0..n * 3 {
                let bound = tol * want[i].abs().max(1.0);
                assert!((want[i] - got[i]).abs() <= bound,
                        "{precision:?} i={i}: ref {} vs quantized {}",
                        want[i], got[i]);
            }
        }
        // a forced portable f32 request is the bit-exact contract
        let portable = KernelPolicy { isa: IsaRequest::Portable,
                                      precision: Precision::F32 };
        let mlp = NativeMlp::from_flat_with(&info, &flat, portable).unwrap();
        assert_eq!(mlp.determinism_tier(), DeterminismTier::BitExact);
        assert_eq!(mlp.isa(), Isa::Portable);
    }
}
