//! Typed loader for `artifacts/manifest.json` (written by aot.py).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::schedule::DdpmSchedule;
use crate::util::Json;

/// Ground-truth target distribution parameters (for quality metrics).
#[derive(Debug, Clone)]
pub enum TargetSpec {
    /// Isotropic GMM: per-component means (row-major), sigmas, weights.
    Gmm { means: Vec<Vec<f64>>, sigmas: Vec<f64>, weights: Vec<f64> },
    /// Procedural 8x8 textures.
    Pixel64 { side: usize, freq: (f64, f64), amp: (f64, f64), noise: f64 },
    /// A robot-control task (see env module).
    Env { task: String },
}

#[derive(Debug, Clone)]
pub struct VariantInfo {
    pub name: String,
    pub d: usize,
    pub cond_dim: usize,
    pub hidden: usize,
    pub layers: usize,
    pub temb_dim: usize,
    pub k_steps: usize,
    pub train_loss: f64,
    /// batch size -> HLO artifact filename
    pub artifacts: BTreeMap<usize, String>,
    pub weights_file: String,
    /// [(n_in, n_out)] per linear layer
    pub weights_layout: Vec<(usize, usize)>,
    pub abar: Vec<f64>,
    pub target: TargetSpec,
    pub env: Option<String>,
}

impl VariantInfo {
    pub fn schedule(&self) -> DdpmSchedule {
        DdpmSchedule::from_abar(self.abar.clone())
    }

    /// Synthetic in-memory variant for tests and benches: input layer
    /// → `blocks` residual hidden blocks → output layer, in exactly
    /// the layout `NativeMlp::from_flat` validates, with a geometric
    /// 0.95 `abar` schedule of `k_steps` entries and no artifacts.
    /// The single source of truth for toy layouts — don't hand-roll
    /// `weights_layout` in test scaffolding.
    pub fn toy(name: &str, d: usize, cond_dim: usize, hidden: usize,
               blocks: usize, k_steps: usize) -> VariantInfo {
        let temb_dim = crate::model::mlp::TEMB_DIM;
        let mut layout = vec![(d + temb_dim + cond_dim, hidden)];
        for _ in 0..blocks {
            layout.push((hidden, hidden));
        }
        layout.push((hidden, d));
        VariantInfo {
            name: name.into(),
            d,
            cond_dim,
            hidden,
            layers: blocks + 1,
            temb_dim,
            k_steps,
            train_loss: 0.0,
            artifacts: Default::default(),
            weights_file: String::new(),
            weights_layout: layout,
            abar: (1..=k_steps).map(|i| 0.95f64.powi(i as i32)).collect(),
            target: TargetSpec::Env { task: name.into() },
            env: None,
        }
    }

    /// Total f32 count of the flat weights buffer this layout expects.
    pub fn weights_len(&self) -> usize {
        self.weights_layout.iter().map(|(a, b)| a * b + b).sum()
    }

    /// Smallest compiled batch size >= n (None if n exceeds the max).
    pub fn batch_for(&self, n: usize) -> Option<usize> {
        self.artifacts.keys().copied().find(|&b| b >= n)
    }

    pub fn max_batch(&self) -> usize {
        self.artifacts.keys().copied().max().unwrap_or(1)
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub beta_start: f64,
    pub beta_end: f64,
    pub spec_t: usize,
    pub chunk: usize,
    pub exec_steps: usize,
    pub variants: BTreeMap<String, VariantInfo>,
    /// d -> speculate / verify kernel artifact filenames
    pub speculate_kernels: BTreeMap<usize, String>,
    pub verify_kernels: BTreeMap<usize, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        Self::from_json(dir, &j)
    }

    pub fn load_default() -> Result<Manifest> {
        let dir = crate::artifacts_dir();
        Self::load(&dir).with_context(|| {
            format!(
                "loading manifest from {} (run `make artifacts` first, or \
                 set ASD_ARTIFACTS)",
                dir.display()
            )
        })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantInfo> {
        self.variants.get(name).with_context(|| {
            format!(
                "unknown variant '{name}' (have: {})",
                self.variants.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    fn from_json(dir: &Path, j: &Json) -> Result<Manifest> {
        let ver = j.get("format_version")?.as_i64()?;
        if ver != 1 {
            bail!("unsupported manifest format_version {ver}");
        }
        let mut variants = BTreeMap::new();
        for (name, v) in j.get("variants")?.as_obj()? {
            variants.insert(name.clone(), parse_variant(name, v)
                .with_context(|| format!("variant '{name}'"))?);
        }
        let parse_kernels = |key: &str| -> Result<BTreeMap<usize, String>> {
            let mut out = BTreeMap::new();
            for (d, f) in j.get("kernels")?.get(key)?.as_obj()? {
                out.insert(d.parse::<usize>()?, f.as_str()?.to_string());
            }
            Ok(out)
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            beta_start: j.get("beta_start")?.as_f64()?,
            beta_end: j.get("beta_end")?.as_f64()?,
            spec_t: j.get("spec_t")?.as_usize()?,
            chunk: j.get("chunk")?.as_usize()?,
            exec_steps: j.get("exec_steps")?.as_usize()?,
            variants,
            speculate_kernels: parse_kernels("speculate")?,
            verify_kernels: parse_kernels("verify")?,
        })
    }
}

fn parse_variant(name: &str, v: &Json) -> Result<VariantInfo> {
    let mut artifacts = BTreeMap::new();
    for (b, f) in v.get("artifacts")?.as_obj()? {
        artifacts.insert(b.parse::<usize>()?, f.as_str()?.to_string());
    }
    let layout = v
        .get("weights_layout")?
        .as_arr()?
        .iter()
        .map(|p| {
            let pair = p.as_arr()?;
            Ok((pair[0].as_usize()?, pair[1].as_usize()?))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(VariantInfo {
        name: name.to_string(),
        d: v.get("d")?.as_usize()?,
        cond_dim: v.get("cond_dim")?.as_usize()?,
        hidden: v.get("hidden")?.as_usize()?,
        layers: v.get("layers")?.as_usize()?,
        temb_dim: v.get("temb_dim")?.as_usize()?,
        k_steps: v.get("k_steps")?.as_usize()?,
        train_loss: v.get("train_loss")?.as_f64()?,
        artifacts,
        weights_file: v.get("weights")?.as_str()?.to_string(),
        weights_layout: layout,
        abar: v.get("abar")?.as_f64_vec()?,
        target: parse_target(v.get("target")?)?,
        env: v.opt("env").map(|e| e.as_str().map(str::to_string)).transpose()?,
    })
}

fn parse_target(t: &Json) -> Result<TargetSpec> {
    match t.get("kind")?.as_str()? {
        "gmm" => {
            let (_, _, _) = t.get("means")?.as_f64_matrix()?;
            let means = t
                .get("means")?
                .as_arr()?
                .iter()
                .map(|r| r.as_f64_vec())
                .collect::<Result<Vec<_>>>()?;
            Ok(TargetSpec::Gmm {
                means,
                sigmas: t.get("sigmas")?.as_f64_vec()?,
                weights: t.get("weights")?.as_f64_vec()?,
            })
        }
        "pixel64" => Ok(TargetSpec::Pixel64 {
            side: t.get("side")?.as_usize()?,
            freq: {
                let f = t.get("freq")?.as_f64_vec()?;
                (f[0], f[1])
            },
            amp: {
                let a = t.get("amp")?.as_f64_vec()?;
                (a[0], a[1])
            },
            noise: t.get("noise")?.as_f64()?,
        }),
        "env" => Ok(TargetSpec::Env {
            task: t.get("task")?.as_str()?.to_string(),
        }),
        k => bail!("unknown target kind '{k}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest() -> Json {
        Json::parse(
            r#"{
            "format_version": 1,
            "beta_start": 0.0001, "beta_end": 0.02,
            "spec_t": 32, "batch_sizes": [1, 2], "chunk": 16,
            "exec_steps": 8,
            "variants": {
              "toy": {
                "d": 2, "cond_dim": 0, "hidden": 8, "layers": 1,
                "temb_dim": 32, "k_steps": 10, "train_loss": 0.5,
                "weights": "w.bin",
                "weights_layout": [[34, 8], [8, 2]],
                "artifacts": {"1": "a1.hlo.txt", "2": "a2.hlo.txt"},
                "abar": [0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3, 0.2, 0.1, 0.05],
                "target": {"kind": "gmm", "means": [[1, 0], [0, 1]],
                           "sigmas": [0.1, 0.1], "weights": [0.5, 0.5]},
                "env": null
              }
            },
            "kernels": {"speculate": {"2": "s.hlo.txt"},
                        "verify": {"2": "v.hlo.txt"}}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_mini_manifest() {
        let m = Manifest::from_json(Path::new("/tmp"), &mini_manifest()).unwrap();
        let v = m.variant("toy").unwrap();
        assert_eq!(v.d, 2);
        assert_eq!(v.k_steps, 10);
        assert_eq!(v.batch_for(2), Some(2));
        assert_eq!(v.batch_for(1), Some(1));
        assert_eq!(v.batch_for(3), None);
        assert_eq!(v.max_batch(), 2);
        assert!(matches!(v.target, TargetSpec::Gmm { .. }));
        assert!(v.env.is_none());
        assert_eq!(m.speculate_kernels[&2], "s.hlo.txt");
    }

    #[test]
    fn schedule_from_abar_is_consistent() {
        let m = Manifest::from_json(Path::new("/tmp"), &mini_manifest()).unwrap();
        let s = m.variant("toy").unwrap().schedule();
        assert_eq!(s.k_steps, 10);
        // abar reproduced
        for (i, &a) in m.variant("toy").unwrap().abar.iter().enumerate() {
            assert!((s.abar[i] - a).abs() < 1e-12);
        }
    }

    #[test]
    fn unknown_variant_error_lists_names() {
        let m = Manifest::from_json(Path::new("/tmp"), &mini_manifest()).unwrap();
        let err = m.variant("nope").unwrap_err().to_string();
        assert!(err.contains("toy"), "{err}");
    }
}
