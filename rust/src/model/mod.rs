//! Models: the `DenoiseModel` abstraction plus its implementations.
//!
//! * [`manifest`] — typed loader for artifacts/manifest.json.
//! * [`mlp`] — rust-native MLP forward over `weights_*.bin` (parity
//!   oracle for the HLO path + a fast fallback backend); batched calls
//!   run as a GEMM pipeline with a reusable workspace (see
//!   `math::gemm`).
//! * [`gmm`] — analytic posterior-mean oracles for GMM targets (exact
//!   `E[x0 | y_i]` / SL `m(t, y)`; drives the theory benches with zero
//!   network error).
//! * [`targets`] — ground-truth target distributions mirrored from
//!   python/compile/targets.py (samplers + Bayes class posteriors for
//!   the quality metrics).
//! * [`parallel`] — sharded-execution decorator running `denoise_batch`
//!   rows concurrently on the global worker pool (bit-identical
//!   outputs; see rust/src/runtime/pool.rs).
//! * [`distill`] — deterministic width-fold distillation producing the
//!   cheap draft variants the draft-speculative sampler pairs with a
//!   target (see `asd::draft`).

pub mod distill;
pub mod gmm;
pub mod manifest;
pub mod mlp;
pub mod parallel;
pub mod targets;

use anyhow::Result;

pub use distill::{distill_draft, synth_group_constant};
pub use gmm::{Gmm, GmmDdpmOracle, GmmSlOracle};
pub use manifest::{Manifest, TargetSpec, VariantInfo};
pub use mlp::{NativeMlp, Workspace};
pub use parallel::ParallelModel;

use crate::runtime::pool::TileGraph;
use crate::sampler::RoundArena;
use crate::schedule::DdpmSchedule;

/// An x0-predicting denoiser with its schedule: the only interface the
/// samplers (sequential / Picard / ASD) touch. `denoise_batch` is "one
/// parallel round" of model calls — the unit Theorem 4 counts.
pub trait DenoiseModel: Send + Sync {
    /// Data dimension d.
    fn dim(&self) -> usize;
    /// Conditioning dimension (0 = unconditional).
    fn cond_dim(&self) -> usize;
    /// Number of DDPM steps K.
    fn k_steps(&self) -> usize;
    /// The DDPM schedule this model was trained under.
    fn schedule(&self) -> &DdpmSchedule;

    /// Batched x0hat prediction.
    ///
    /// `ys`: n*d row-major iterates; `ts`: n step indices (1..=K);
    /// `cond`: n*cond_dim conditioning rows; `out`: n*d output buffer.
    fn denoise_batch(&self, ys: &[f64], ts: &[f64], cond: &[f64], n: usize,
                     out: &mut [f64]) -> Result<()>;

    /// Execute one staged arena round: consume the arena's input
    /// region and fill its output region in place (the zero-copy round
    /// data plane — see `sampler::RoundArena`). The default forwards to
    /// `denoise_batch` on the arena's views; backends with a cheaper
    /// arena path override it (`ParallelModel` shards arena rows on the
    /// global pool, `NativeMlp` converts f64→f32 once per round into
    /// the arena's GEMM workspace). Must be bit-identical to the
    /// `denoise_batch` form.
    fn denoise_round(&self, arena: &mut RoundArena) -> Result<()> {
        let (ys, ts, cond, n, out) = arena.round_io();
        self.denoise_batch(ys, ts, cond, n, out)
    }

    /// Compile one staged arena round into a barrier-free
    /// [`TileGraph`] for the caller to execute on the worker pool
    /// instead of calling [`denoise_round`](Self::denoise_round).
    /// Backends that can express a round as dependency-counted tiles
    /// (`NativeMlp`: pack → per-(row-block, column-panel) GEMM tiles
    /// per layer → store) return `Some(graph)`; the graph must be
    /// bit-identical to `denoise_round` under every execution order
    /// the dependencies admit. The returned graph holds raw pointers
    /// into the arena and the model — the caller must keep both alive
    /// and untouched until the graph has fully executed. Default:
    /// `None` (no graph form; the caller falls back to
    /// `denoise_round`).
    fn compile_round(&self, _arena: &mut RoundArena)
                     -> Result<Option<TileGraph>> {
        Ok(None)
    }

    /// Worker-pool shards a `denoise_round` over an `n`-row arena
    /// would occupy — stats only (`RoundExec::shards`, lane occupancy
    /// metrics). The default is serial; `ParallelModel` overrides it
    /// with the same routing decision `denoise_round` makes (row
    /// shards, or the graph tile budget for small-M rounds), so
    /// reported occupancy tracks what actually ran.
    fn round_shards(&self, _n: usize) -> usize {
        1
    }

    /// Intra-round pool fork/join barriers an `n`-row `denoise_round`
    /// performs — feeds the coordinator's layer-boundary stall
    /// estimate, and doubles as the graph-capability advertisement:
    /// `ParallelModel` routes rounds to `compile_round` exactly when
    /// the inner backend reports 0 here (a barrier-free backend is by
    /// construction one whose rounds compile to a tile graph). The
    /// legacy default (one joined parallel region) is 1.
    fn round_barriers(&self, _n: usize) -> usize {
        1
    }

    /// Convenience single-call wrapper.
    fn denoise_one(&self, y: &[f64], t: usize, cond: &[f64],
                   out: &mut [f64]) -> Result<()> {
        self.denoise_batch(y, &[t as f64], cond, 1, out)
    }
}
