//! Analytic Gaussian-mixture oracles.
//!
//! For an isotropic GMM target the posterior mean is closed-form under
//! both parametrizations, giving *exact* (zero network error) models:
//!
//! * DDPM form:  y_i = sqrt(abar) x0 + sqrt(1-abar) eps
//!     r_c ∝ w_c N(y; sqrt(abar) mu_c, (abar sig_c^2 + 1 - abar) I)
//!     E[x0|y,c] = mu_c + sqrt(abar) sig_c^2 / var_c (y - sqrt(abar) mu_c)
//! * SL form (Thm 8): y_t = t x* + W_t
//!     r_c ∝ w_c N(y; t mu_c, (t^2 sig_c^2 + t) I)
//!     E[x|y,c] = mu_c + t sig_c^2 / (t^2 sig_c^2 + t) (y - t mu_c)
//!
//! These drive the Thm-4 scaling benches and the exactness tests — the
//! algorithmic claims are checked unconfounded by learning error.

use std::sync::Arc;

use anyhow::Result;

use crate::model::{DenoiseModel, TargetSpec};
use crate::rng::Philox;
use crate::schedule::DdpmSchedule;

/// An isotropic Gaussian mixture in R^d.
#[derive(Debug, Clone)]
pub struct Gmm {
    pub d: usize,
    /// component means, row-major (c, d)
    pub means: Vec<f64>,
    pub sigmas: Vec<f64>,
    pub weights: Vec<f64>,
}

impl Gmm {
    pub fn new(means: Vec<Vec<f64>>, sigmas: Vec<f64>, weights: Vec<f64>) -> Gmm {
        let d = means[0].len();
        let flat: Vec<f64> = means.into_iter().flatten().collect();
        Gmm { d, means: flat, sigmas, weights }
    }

    pub fn from_target(t: &TargetSpec) -> Option<Gmm> {
        match t {
            TargetSpec::Gmm { means, sigmas, weights } => {
                Some(Gmm::new(means.clone(), sigmas.clone(), weights.clone()))
            }
            _ => None,
        }
    }

    /// The paper's gmm2d toy target (8 modes on a circle) for tests.
    pub fn circle_2d() -> Gmm {
        let c = 8;
        let means = (0..c)
            .map(|i| {
                let a = 2.0 * std::f64::consts::PI * i as f64 / c as f64;
                vec![1.5 * a.cos(), 1.5 * a.sin()]
            })
            .collect();
        Gmm::new(means, vec![0.12; c], vec![1.0 / c as f64; c])
    }

    /// Random isotropic GMM in R^d — the heavy synthetic workload for
    /// the parallel-execution benches and tests: the posterior-mean cost
    /// scales with `components * d`, so wide mixtures make per-row
    /// denoise work big enough for sharding to pay off.
    pub fn random(d: usize, components: usize, spread: f64, seed: u64) -> Gmm {
        let mut rng = Philox::new(seed, 77);
        let means: Vec<Vec<f64>> = (0..components)
            .map(|_| (0..d).map(|_| spread * rng.normal()).collect())
            .collect();
        let sigmas: Vec<f64> =
            (0..components).map(|_| 0.15 + 0.1 * rng.uniform()).collect();
        let weights = vec![1.0 / components as f64; components];
        Gmm::new(means, sigmas, weights)
    }

    pub fn n_components(&self) -> usize {
        self.weights.len()
    }

    pub fn mean_of(&self, c: usize) -> &[f64] {
        &self.means[c * self.d..(c + 1) * self.d]
    }

    /// Draw a sample; returns (x, component).
    pub fn sample(&self, rng: &mut Philox) -> (Vec<f64>, usize) {
        let u = rng.uniform();
        let mut acc = 0.0;
        let mut comp = self.n_components() - 1;
        for (c, &w) in self.weights.iter().enumerate() {
            acc += w;
            if u < acc {
                comp = c;
                break;
            }
        }
        let mu = self.mean_of(comp);
        let x = (0..self.d)
            .map(|i| mu[i] + self.sigmas[comp] * rng.normal())
            .collect();
        (x, comp)
    }

    /// Bayes posterior P(component | x) under the target itself — the
    /// alignment (CLIP-proxy) metric for conditional variants.
    pub fn class_posterior(&self, x: &[f64]) -> Vec<f64> {
        let mut logp: Vec<f64> = (0..self.n_components())
            .map(|c| {
                let mu = self.mean_of(c);
                let s2 = self.sigmas[c] * self.sigmas[c];
                let d2: f64 = x.iter().zip(mu).map(|(a, b)| (a - b) * (a - b)).sum();
                self.weights[c].ln() - 0.5 * d2 / s2
                    - 0.5 * self.d as f64 * s2.ln()
            })
            .collect();
        let mx = logp.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut sum = 0.0;
        for l in logp.iter_mut() {
            *l = (*l - mx).exp();
            sum += *l;
        }
        for l in logp.iter_mut() {
            *l /= sum;
        }
        logp
    }

    /// Posterior mean E[x0 | y, noise level abar] (responsibilities and
    /// per-component conditional means; `cond_class` restricts to one
    /// component — the conditional-model case).
    pub fn ddpm_posterior_mean(&self, y: &[f64], abar: f64,
                               cond_class: Option<usize>, out: &mut [f64]) {
        let sa = abar.sqrt();
        let classes: Vec<usize> = match cond_class {
            Some(c) => vec![c],
            None => (0..self.n_components()).collect(),
        };
        let mut logr = Vec::with_capacity(classes.len());
        for &c in &classes {
            let s2 = self.sigmas[c] * self.sigmas[c];
            let var = abar * s2 + (1.0 - abar);
            let mu = self.mean_of(c);
            let d2: f64 = y.iter().zip(mu).map(|(a, b)| {
                let diff = a - sa * b;
                diff * diff
            }).sum();
            logr.push(self.weights[c].ln() - 0.5 * d2 / var
                - 0.5 * self.d as f64 * var.ln());
        }
        let mx = logr.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut rs: Vec<f64> = logr.iter().map(|l| (l - mx).exp()).collect();
        let sum: f64 = rs.iter().sum();
        for r in rs.iter_mut() {
            *r /= sum;
        }
        out.fill(0.0);
        for (r, &c) in rs.iter().zip(&classes) {
            let s2 = self.sigmas[c] * self.sigmas[c];
            let var = abar * s2 + (1.0 - abar);
            let gain = sa * s2 / var;
            let mu = self.mean_of(c);
            for i in 0..self.d {
                out[i] += r * (mu[i] + gain * (y[i] - sa * mu[i]));
            }
        }
    }

    /// SL posterior mean m(t, y) (Eq. 4) for the SL-native theory path.
    pub fn sl_posterior_mean(&self, y: &[f64], t: f64, out: &mut [f64]) {
        if t <= 0.0 {
            // t=0: no information; m = prior mean
            out.fill(0.0);
            for c in 0..self.n_components() {
                let mu = self.mean_of(c);
                for i in 0..self.d {
                    out[i] += self.weights[c] * mu[i];
                }
            }
            return;
        }
        let mut logr = Vec::with_capacity(self.n_components());
        for c in 0..self.n_components() {
            let s2 = self.sigmas[c] * self.sigmas[c];
            let var = t * t * s2 + t;
            let mu = self.mean_of(c);
            let d2: f64 = y.iter().zip(mu).map(|(a, b)| {
                let diff = a - t * b;
                diff * diff
            }).sum();
            logr.push(self.weights[c].ln() - 0.5 * d2 / var
                - 0.5 * self.d as f64 * var.ln());
        }
        let mx = logr.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut rs: Vec<f64> = logr.iter().map(|l| (l - mx).exp()).collect();
        let sum: f64 = rs.iter().sum();
        for r in rs.iter_mut() {
            *r /= sum;
        }
        out.fill(0.0);
        for (c, r) in rs.iter().enumerate() {
            let s2 = self.sigmas[c] * self.sigmas[c];
            let gain = t * s2 / (t * t * s2 + t);
            let mu = self.mean_of(c);
            for i in 0..self.d {
                out[i] += r * (mu[i] + gain * (y[i] - t * mu[i]));
            }
        }
    }
}

/// DDPM-form analytic oracle implementing `DenoiseModel`.
pub struct GmmDdpmOracle {
    pub gmm: Gmm,
    schedule: DdpmSchedule,
    /// interpret the conditioning one-hot as a class restriction
    pub conditional: bool,
}

impl GmmDdpmOracle {
    pub fn new(gmm: Gmm, k_steps: usize, conditional: bool) -> Arc<GmmDdpmOracle> {
        Arc::new(GmmDdpmOracle { gmm, schedule: DdpmSchedule::new(k_steps), conditional })
    }
}

impl DenoiseModel for GmmDdpmOracle {
    fn dim(&self) -> usize {
        self.gmm.d
    }

    fn cond_dim(&self) -> usize {
        if self.conditional { self.gmm.n_components() } else { 0 }
    }

    fn k_steps(&self) -> usize {
        self.schedule.k_steps
    }

    fn schedule(&self) -> &DdpmSchedule {
        &self.schedule
    }

    fn denoise_batch(&self, ys: &[f64], ts: &[f64], cond: &[f64], n: usize,
                     out: &mut [f64]) -> Result<()> {
        let d = self.gmm.d;
        let c_dim = self.cond_dim();
        for r in 0..n {
            let i = ts[r] as usize;
            let abar = self.schedule.abar[i - 1];
            let cls = if self.conditional {
                let row = &cond[r * c_dim..(r + 1) * c_dim];
                Some(row.iter().enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(idx, _)| idx).unwrap())
            } else {
                None
            };
            self.gmm.ddpm_posterior_mean(
                &ys[r * d..(r + 1) * d], abar, cls, &mut out[r * d..(r + 1) * d]);
        }
        Ok(())
    }
}

/// SL-form oracle m(t, y) for SL-native sampling (theory benches).
pub struct GmmSlOracle {
    pub gmm: Gmm,
}

impl GmmSlOracle {
    /// Batched m(t, y).
    pub fn mean_batch(&self, ys: &[f64], times: &[f64], n: usize, out: &mut [f64]) {
        let d = self.gmm.d;
        for r in 0..n {
            self.gmm.sl_posterior_mean(&ys[r * d..(r + 1) * d], times[r],
                                       &mut out[r * d..(r + 1) * d]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn posterior_mean_at_zero_noise_is_identity_ish() {
        // abar -> 1: y ~= x0, posterior mean should return ~y when y is
        // exactly on a mode
        let gmm = Gmm::circle_2d();
        let mut out = vec![0.0; 2];
        let y = gmm.mean_of(0).to_vec();
        gmm.ddpm_posterior_mean(&y, 0.999999, None, &mut out);
        assert!((out[0] - y[0]).abs() < 1e-3 && (out[1] - y[1]).abs() < 1e-3);
    }

    #[test]
    fn posterior_mean_at_full_noise_is_prior_mean() {
        // abar -> 0: no information; E[x0] = overall mean = 0 for the circle
        let gmm = Gmm::circle_2d();
        let mut out = vec![0.0; 2];
        gmm.ddpm_posterior_mean(&[3.0, -1.0], 1e-12, None, &mut out);
        // O(sqrt(abar)) residue from the responsibilities' y-dependence
        assert!(out[0].abs() < 1e-4 && out[1].abs() < 1e-4);
    }

    #[test]
    fn conditional_restricts_to_component() {
        let gmm = Gmm::circle_2d();
        let mut out = vec![0.0; 2];
        // far-away y, conditioned on component 3: mean must pull to mu_3
        gmm.ddpm_posterior_mean(&[0.0, 0.0], 1e-9, Some(3), &mut out);
        let mu3 = gmm.mean_of(3);
        assert!((out[0] - mu3[0]).abs() < 1e-6);
        assert!((out[1] - mu3[1]).abs() < 1e-6);
    }

    #[test]
    fn class_posterior_peaks_at_nearest_mode() {
        let gmm = Gmm::circle_2d();
        let p = gmm.class_posterior(gmm.mean_of(5));
        let argmax = p.iter().enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert_eq!(argmax, 5);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sl_mean_localizes_to_sample() {
        // large t: m(t, t*x + W_t) ~= x for x on a mode
        let gmm = Gmm::circle_2d();
        let x = gmm.mean_of(2);
        let t = 5000.0;
        let y: Vec<f64> = x.iter().map(|v| t * v).collect();
        let mut m = vec![0.0; 2];
        gmm.sl_posterior_mean(&y, t, &mut m);
        assert!((m[0] - x[0]).abs() < 1e-3 && (m[1] - x[1]).abs() < 1e-3);
    }

    #[test]
    fn sl_mean_at_t0_is_prior_mean() {
        let gmm = Gmm::circle_2d();
        let mut m = vec![9.0; 2];
        gmm.sl_posterior_mean(&[0.0, 0.0], 0.0, &mut m);
        assert!(m[0].abs() < 1e-12 && m[1].abs() < 1e-12);
    }

    #[test]
    fn samples_hit_modes() {
        let gmm = Gmm::circle_2d();
        let mut rng = Philox::new(11, 0);
        for _ in 0..200 {
            let (x, c) = gmm.sample(&mut rng);
            let mu = gmm.mean_of(c);
            let dist = ((x[0] - mu[0]).powi(2) + (x[1] - mu[1]).powi(2)).sqrt();
            assert!(dist < 0.12 * 6.0, "sample too far from its mode");
        }
    }

    #[test]
    fn oracle_denoise_model_impl() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 50, false);
        assert_eq!(oracle.dim(), 2);
        assert_eq!(oracle.k_steps(), 50);
        let mut out = vec![0.0; 4];
        oracle.denoise_batch(&[0.1, 0.2, -0.3, 0.4], &[50.0, 1.0], &[], 2,
                             &mut out).unwrap();
        // noise level 50 (max): near prior mean; level 1: near the iterate
        assert!(out[0].abs() < 0.5);
    }
}
