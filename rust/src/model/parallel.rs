//! `ParallelModel` — sharded-execution decorator over any
//! [`DenoiseModel`].
//!
//! Wraps an inner model and splits every `denoise_batch(n, ...)` call
//! into contiguous per-shard row ranges executed concurrently on the
//! process-global worker pool ([`crate::runtime::pool::global`]). Each
//! row's computation happens entirely inside the inner model exactly as
//! it would unsharded, so outputs are **bit-identical for every
//! `pool_size` and every work-stealing schedule** — the pool decides
//! *which thread* runs a shard (stealing moves shards between workers
//! under load), never the shard partition or any reduction order, so
//! scheduling changes wall-clock, never samples (the float summation
//! order per sample is untouched). With the ISA-dispatched GEMM
//! backends (`math::isa`) this invariance holds *within a fixed
//! kernel configuration*: the resolved ISA and panel precision are
//! frozen per model at load, so pool size and steal schedules still
//! never flip a bit, but two hosts resolving different ISAs (or two
//! `KernelPolicy`s) sit in different determinism tiers and may differ
//! from each other by FMA/quantization rounding. This composes with
//! `NativeMlp`'s GEMM batch path: each shard runs the whole pipeline
//! on its row range against its own thread-local workspace, and the
//! GEMM reduction order is row-independent by construction (see
//! `math::gemm`), so wrapping the MLP stays bit-transparent too.
//! Arena rounds against a graph-capable backend (`NativeMlp`) skip
//! row sharding entirely: the round compiles to the backend's
//! dependency-counted tile graph (`DenoiseModel::compile_round`) and
//! executes barrier-free on the pool — row blocks flow through the
//! layers independently, and small-M serving rounds fan out over
//! column panels. Row sharding remains the route for slice
//! `denoise_batch` calls and for backends without a graph form (the
//! analytic oracles); `math::gemm::gemm_sharded` exists for the
//! complementary case of one very large standalone product.
//!
//! HLO-backed models note: `HloModel` pads batches up to the nearest
//! compiled size, so sharding changes the padding pattern and may
//! perturb f32 results within artifact tolerance. The bit-exactness
//! guarantee is for row-independent native models (the analytic oracles
//! and `NativeMlp`); parity tests pin both.

use std::sync::Arc;

use anyhow::Result;

use crate::model::DenoiseModel;
use crate::runtime::pool::{self, PoolConfig};
use crate::sampler::RoundArena;
use crate::schedule::DdpmSchedule;

/// Raw output pointer smuggled into `Fn` shards; sound because shards
/// write disjoint row ranges and the pool joins before the call returns.
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

pub struct ParallelModel {
    inner: Arc<dyn DenoiseModel>,
    pub pool: PoolConfig,
}

impl ParallelModel {
    pub fn new(inner: Arc<dyn DenoiseModel>, pool: PoolConfig)
               -> Arc<ParallelModel> {
        Arc::new(ParallelModel { inner, pool })
    }

    /// Wrap only when the config actually shards; `pool_size <= 1`
    /// returns the inner model untouched (zero overhead).
    pub fn wrap(inner: Arc<dyn DenoiseModel>, pool: PoolConfig)
                -> Arc<dyn DenoiseModel> {
        if pool.parallel() {
            Arc::new(ParallelModel { inner, pool })
        } else {
            inner
        }
    }

    /// Shard occupancy an `n`-row call would get.
    pub fn occupancy(&self, n: usize) -> usize {
        self.pool.shards_for(n)
    }

    /// The single routing predicate `denoise_round`, `compile_round`,
    /// and the stats methods share: whether an `n`-row round executes
    /// as the inner backend's compiled tile graph on the pool.
    /// Graph-capable backends advertise themselves by reporting zero
    /// [`DenoiseModel::round_barriers`]; past the `shard_min` inline
    /// guard every such round — even ones with too few rows to
    /// row-shard — fans out over the whole pool through the graph's
    /// column-panel tiles.
    fn graph_round(&self, n: usize) -> bool {
        self.inner.round_barriers(n) == 0
            && (self.pool.shards_for(n) > 1
                || n > self.pool.shard_min.max(1))
    }
}

impl DenoiseModel for ParallelModel {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn cond_dim(&self) -> usize {
        self.inner.cond_dim()
    }

    fn k_steps(&self) -> usize {
        self.inner.k_steps()
    }

    fn schedule(&self) -> &DdpmSchedule {
        self.inner.schedule()
    }

    fn denoise_batch(&self, ys: &[f64], ts: &[f64], cond: &[f64], n: usize,
                     out: &mut [f64]) -> Result<()> {
        let shards = self.pool.shards_for(n);
        if shards <= 1 {
            return self.inner.denoise_batch(ys, ts, cond, n, out);
        }
        let d = self.inner.dim();
        let c = self.inner.cond_dim();
        anyhow::ensure!(ys.len() == n * d && ts.len() == n
                            && cond.len() == n * c && out.len() >= n * d,
                        "parallel denoise_batch shape mismatch: n={n} d={d} \
                         c={c} ys={} ts={} cond={} out={}",
                        ys.len(), ts.len(), cond.len(), out.len());
        let first_err: std::sync::Mutex<Option<anyhow::Error>> =
            std::sync::Mutex::new(None);
        let out_ptr = SendPtr(out.as_mut_ptr());
        let inner = &self.inner;
        pool::global().run_sharded(n, shards, |start, end| {
            let rows = end - start;
            // SAFETY: shard ranges are disjoint and the pool joins
            // before `out` is touched again — no aliasing.
            let shard_out = unsafe {
                std::slice::from_raw_parts_mut(
                    out_ptr.0.add(start * d), rows * d)
            };
            if let Err(e) = inner.denoise_batch(
                &ys[start * d..end * d],
                &ts[start..end],
                &cond[start * c..end * c],
                rows,
                shard_out,
            ) {
                let mut guard = first_err.lock().unwrap();
                if guard.is_none() {
                    *guard = Some(e);
                }
            }
        });
        match first_err.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Arena rounds route through one predicate (`graph_round`):
    /// backends that compile barrier-free tile graphs execute every
    /// round past the inline guard as a graph on the pool (this
    /// subsumes both the old row-shard and 2-D-tiled routes — the
    /// graph partitions over row blocks *and* column panels, so a
    /// 4-row fused serving round still occupies the whole pool through
    /// its column-panel tiles, with zero intra-round fork/joins).
    /// Non-graph backends keep the contiguous row-shard route: pure
    /// subslicing of the arena's input region, one `denoise_batch`
    /// per shard. Either way outputs stay bit-identical to inline
    /// execution — the graph never changes a partition or reduction
    /// order, and row shards never split a row.
    fn denoise_round(&self, arena: &mut RoundArena) -> Result<()> {
        if let Some(graph) = self.compile_round(arena)? {
            pool::global().run_graph(graph);
            return Ok(());
        }
        if self.pool.shards_for(arena.rows()) <= 1 {
            return self.inner.denoise_round(arena);
        }
        let (ys, ts, cond, n, out) = arena.round_io();
        self.denoise_batch(ys, ts, cond, n, out)
    }

    /// Rounds the routing predicate sends to the graph path compile to
    /// the inner backend's tile graph; others return `None`, telling
    /// callers (the coordinator driver, `denoise_round` above) to fall
    /// back to `denoise_round`'s row-shard / inline routes.
    fn compile_round(&self, arena: &mut RoundArena)
                     -> Result<Option<crate::runtime::pool::TileGraph>> {
        if self.graph_round(arena.rows()) {
            self.inner.compile_round(arena)
        } else {
            Ok(None)
        }
    }

    /// Stats-only view of the routing above: the whole pool for graph
    /// rounds, the row-shard count otherwise — so occupancy metrics
    /// report what actually ran.
    fn round_shards(&self, n: usize) -> usize {
        if self.graph_round(n) {
            self.pool.pool_size
        } else {
            self.pool.shards_for(n)
        }
    }

    /// Graph rounds are barrier-free; row-sharded rounds fork/join the
    /// pool once; inline rounds inherit the inner model's count.
    fn round_barriers(&self, n: usize) -> usize {
        if self.graph_round(n) {
            0
        } else if self.pool.shards_for(n) > 1 {
            1
        } else {
            self.inner.round_barriers(n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Gmm, GmmDdpmOracle};

    fn oracle(k: usize) -> Arc<GmmDdpmOracle> {
        GmmDdpmOracle::new(Gmm::circle_2d(), k, false)
    }

    #[test]
    fn wrap_is_identity_for_pool_size_one() {
        let base = oracle(20);
        let wrapped = ParallelModel::wrap(base.clone(), PoolConfig::default());
        // same underlying allocation: no decorator layer was added
        assert_eq!(Arc::as_ptr(&wrapped) as *const (),
                   Arc::as_ptr(&base) as *const ());
    }

    #[test]
    fn sharded_matches_inline_bitwise() {
        let base = oracle(30);
        let par = ParallelModel::new(
            base.clone(), PoolConfig { pool_size: 4, shard_min: 1 });
        for n in [1usize, 3, 4, 5, 11] {
            let ys: Vec<f64> =
                (0..n * 2).map(|i| (i as f64 * 0.37).sin()).collect();
            let ts: Vec<f64> = (0..n).map(|r| (1 + r % 30) as f64).collect();
            let mut want = vec![0.0; n * 2];
            base.denoise_batch(&ys, &ts, &[], n, &mut want).unwrap();
            let mut got = vec![0.0; n * 2];
            par.denoise_batch(&ys, &ts, &[], n, &mut got).unwrap();
            let want_bits: Vec<u64> =
                want.iter().map(|v| v.to_bits()).collect();
            let got_bits: Vec<u64> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(want_bits, got_bits, "n={n}");
        }
    }

    #[test]
    fn arena_round_matches_slice_batch_bitwise() {
        let base = oracle(30);
        let par = ParallelModel::new(
            base.clone(), PoolConfig { pool_size: 4, shard_min: 1 });
        for n in [1usize, 3, 7] {
            let ys: Vec<f64> =
                (0..n * 2).map(|i| (i as f64 * 0.53).cos()).collect();
            let ts: Vec<f64> = (0..n).map(|r| (1 + r % 30) as f64).collect();
            let mut want = vec![0.0; n * 2];
            par.denoise_batch(&ys, &ts, &[], n, &mut want).unwrap();
            let mut arena = RoundArena::new(2, 0);
            arena.begin_round();
            let (span, rows) = arena.reserve(n);
            rows.ys.copy_from_slice(&ys);
            rows.ts.copy_from_slice(&ts);
            par.denoise_round(&mut arena).unwrap();
            let got = arena.out_rows(span);
            let bits = |v: &[f64]| -> Vec<u64> {
                v.iter().map(|x| x.to_bits()).collect()
            };
            assert_eq!(bits(&want), bits(got), "n={n}");
        }
    }

    #[test]
    fn small_rounds_route_to_backend_graph_bit_identically() {
        use crate::model::{NativeMlp, VariantInfo};
        // a native MLP compiles rounds to tile graphs; rounds too
        // small to row-shard must still produce the exact inline bits
        // through the graph route
        let info = VariantInfo::toy("tile", 3, 0, 16, 2, 10);
        let flat: Vec<f32> = (0..info.weights_len())
            .map(|i| ((i * 37 % 101) as f32 / 101.0) - 0.5)
            .collect();
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        // the MLP advertises graph capability via zero round barriers
        assert_eq!(mlp.round_barriers(4), 0);
        // shard_min 1: n=1 stays inline (the shards_for inline guard),
        // n in {2, 4} is too small to fill the pool with row shards
        // and takes the graph route — both must produce the exact
        // inline bits
        let par = ParallelModel::new(
            mlp.clone(), PoolConfig { pool_size: 8, shard_min: 1 });
        for n in [1usize, 2, 4] {
            let ys: Vec<f64> =
                (0..n * 3).map(|i| (i as f64 * 0.31).sin()).collect();
            let ts: Vec<f64> = (0..n).map(|r| (1 + r % 10) as f64).collect();
            let mut want = vec![0.0; n * 3];
            mlp.denoise_batch(&ys, &ts, &[], n, &mut want).unwrap();
            let mut arena = RoundArena::new(3, 0);
            arena.begin_round();
            let (span, rows) = arena.reserve(n);
            rows.ys.copy_from_slice(&ys);
            rows.ts.copy_from_slice(&ts);
            par.denoise_round(&mut arena).unwrap();
            let got = arena.out_rows(span);
            for i in 0..n * 3 {
                assert_eq!(want[i].to_bits(), got[i].to_bits(),
                           "n={n} i={i}");
            }
        }
    }

    #[test]
    fn delegates_model_metadata() {
        let base = oracle(25);
        let par = ParallelModel::new(base.clone(), PoolConfig::sharded(4));
        assert_eq!(par.dim(), base.dim());
        assert_eq!(par.cond_dim(), base.cond_dim());
        assert_eq!(par.k_steps(), 25);
        assert_eq!(par.schedule().k_steps, 25);
        assert_eq!(par.occupancy(1), 1);
        assert!(par.occupancy(16) > 1);
    }

    #[test]
    fn shard_errors_surface() {
        struct Failing(DdpmSchedule);
        impl DenoiseModel for Failing {
            fn dim(&self) -> usize {
                2
            }
            fn cond_dim(&self) -> usize {
                0
            }
            fn k_steps(&self) -> usize {
                self.0.k_steps
            }
            fn schedule(&self) -> &DdpmSchedule {
                &self.0
            }
            fn denoise_batch(&self, _ys: &[f64], ts: &[f64], _cond: &[f64],
                             _n: usize, _out: &mut [f64]) -> Result<()> {
                anyhow::ensure!(ts[0] > 2.0, "injected failure at t={}", ts[0]);
                Ok(())
            }
        }
        let par = ParallelModel::new(
            Arc::new(Failing(DdpmSchedule::new(10))),
            PoolConfig { pool_size: 4, shard_min: 1 });
        let ts: Vec<f64> = (1..=8).map(|t| t as f64).collect();
        let ys = vec![0.0; 16];
        let mut out = vec![0.0; 16];
        let err = par.denoise_batch(&ys, &ts, &[], 8, &mut out).unwrap_err();
        assert!(err.to_string().contains("injected failure"), "{err:#}");
    }
}
