//! Ground-truth target samplers mirrored from python/compile/targets.py
//! (distribution-identical, not sample-identical: rust draws from Philox,
//! python from numpy — the laws match, which is what the quality metrics
//! need).

use crate::model::{Gmm, TargetSpec};
use crate::rng::Philox;

/// Sample `n` ground-truth points from a target spec. For GMM targets
/// also returns the component labels (for conditional evaluation).
pub fn sample_target(spec: &TargetSpec, n: usize, rng: &mut Philox)
                     -> (Vec<Vec<f64>>, Vec<usize>) {
    match spec {
        TargetSpec::Gmm { means, sigmas, weights } => {
            let gmm = Gmm::new(means.clone(), sigmas.clone(), weights.clone());
            let mut xs = Vec::with_capacity(n);
            let mut cs = Vec::with_capacity(n);
            for _ in 0..n {
                let (x, c) = gmm.sample(rng);
                xs.push(x);
                cs.push(c);
            }
            (xs, cs)
        }
        TargetSpec::Pixel64 { side, freq, amp, noise } => {
            let xs = (0..n).map(|_| pixel_texture(*side, *freq, *amp, *noise, rng))
                .collect();
            (xs, vec![0; n])
        }
        TargetSpec::Env { .. } => {
            panic!("env targets are evaluated by rollout, not sampling")
        }
    }
}

/// One procedural texture (oriented sinusoidal grating + pixel noise),
/// mirroring targets.pixel64_sample.
pub fn pixel_texture(side: usize, freq: (f64, f64), amp: (f64, f64),
                     noise: f64, rng: &mut Philox) -> Vec<f64> {
    let f = freq.0 + rng.uniform() * (freq.1 - freq.0);
    let psi = rng.uniform() * std::f64::consts::PI;
    let phase = rng.uniform() * 2.0 * std::f64::consts::PI;
    let a = amp.0 + rng.uniform() * (amp.1 - amp.0);
    let (spsi, cpsi) = psi.sin_cos();
    let mut img = Vec::with_capacity(side * side);
    for i in 0..side {
        for j in 0..side {
            let grid = (cpsi * i as f64 + spsi * j as f64) / side as f64;
            let v = a * (2.0 * std::f64::consts::PI * f * grid + phase).sin()
                + noise * rng.normal();
            img.push(v);
        }
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pixel_texture_stats() {
        let mut rng = Philox::new(3, 0);
        let mut all = Vec::new();
        for _ in 0..200 {
            let img = pixel_texture(8, (1.0, 3.0), (0.5, 1.0), 0.05, &mut rng);
            assert_eq!(img.len(), 64);
            all.extend(img);
        }
        // sinusoid with amplitude in [0.5, 1]: mean ~0, |v| <= ~1.2
        let mean = all.iter().sum::<f64>() / all.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!(all.iter().all(|v| v.abs() < 1.0 + 6.0 * 0.05));
    }

    #[test]
    fn gmm_target_sampling() {
        let spec = TargetSpec::Gmm {
            means: vec![vec![0.0, 0.0], vec![10.0, 10.0]],
            sigmas: vec![0.1, 0.1],
            weights: vec![0.9, 0.1],
        };
        let mut rng = Philox::new(4, 0);
        let (xs, cs) = sample_target(&spec, 2000, &mut rng);
        let n1 = cs.iter().filter(|&&c| c == 1).count();
        assert!((n1 as f64 / 2000.0 - 0.1).abs() < 0.03);
        for (x, &c) in xs.iter().zip(&cs) {
            let expect = if c == 0 { 0.0 } else { 10.0 };
            assert!((x[0] - expect).abs() < 1.0);
        }
    }
}
