//! Deterministic draft-model synthesis: width-fold distillation of a
//! `NativeMlp` variant into a narrow draft for speculative sampling.
//!
//! The draft path (see `asd::draft`) needs a cheap model whose x0hat
//! predictions track the target closely enough that GRS accepts long
//! runs. We obtain one *deterministically* — no training loop — by
//! folding the target's hidden width by an integer factor `fold`:
//! every group of `fold` consecutive hidden units collapses into one
//! draft unit. The folding rule is chosen so that whenever the target's
//! weights are *group-constant* (all units in a group identical), the
//! draft computes exactly the same function:
//!
//! * input layer `(n_in, H) -> (n_in, G)`: mean over each out-group
//!   (group-equal activations stay equal through SiLU);
//! * hidden blocks `(H, H) -> (G, G)`: sum over the in-group of the
//!   mean over the out-group (the sum absorbs the `fold`-fold
//!   replication of equal inputs);
//! * output layer `(H, d) -> (G, d)`: sum over the in-group, bias
//!   unchanged (exact for *any* output weights once the hidden
//!   activations are group-constant);
//! * biases: mean over each out-group (output bias unchanged).
//!
//! On real (non-group-constant) targets the draft is an approximation
//! whose quality degrades smoothly with intra-group weight variance —
//! exactly the accept-rate knob the Pareto bench sweeps. The draft
//! reuses the target's schedule (`abar`), dims and conditioning, so it
//! is loadable through the same `NativeMlp::from_flat` /
//! `from_flat_with` route (and packable to f16/int8 panels).

use anyhow::Result;

use crate::model::VariantInfo;

/// Validate that `info`'s layout is the standard MLP shape (input
/// layer, residual hidden blocks, output layer) and that `fold` evenly
/// divides the hidden width. Returns the draft hidden width.
fn check_fold(info: &VariantInfo, fold: usize) -> Result<usize> {
    anyhow::ensure!(fold >= 1, "fold must be >= 1 (got {fold})");
    let h = info.hidden;
    anyhow::ensure!(h > 0 && h % fold == 0,
                    "hidden width {h} is not divisible by fold {fold}");
    let nl = info.weights_layout.len();
    anyhow::ensure!(nl >= 2, "layout needs input + output layers");
    anyhow::ensure!(info.weights_layout[0].1 == h,
                    "input layer out-width {} != hidden {h}",
                    info.weights_layout[0].1);
    for &(a, b) in &info.weights_layout[1..nl - 1] {
        anyhow::ensure!(a == h && b == h,
                        "hidden block ({a}, {b}) is not ({h}, {h})");
    }
    anyhow::ensure!(info.weights_layout[nl - 1] == (h, info.d),
                    "output layer {:?} != ({h}, {})",
                    info.weights_layout[nl - 1], info.d);
    Ok(h / fold)
}

/// Distill a flat target weight buffer into a width-folded draft.
/// Returns the draft's `VariantInfo` (same dims/schedule, hidden width
/// divided by `fold`, name suffixed `-draft{fold}`, no artifacts) and
/// its flat weight buffer, loadable via `NativeMlp::from_flat[_with]`.
pub fn distill_draft(info: &VariantInfo, flat: &[f32], fold: usize)
                     -> Result<(VariantInfo, Vec<f32>)> {
    let g = check_fold(info, fold)?;
    anyhow::ensure!(flat.len() == info.weights_len(),
                    "flat weights length {} != layout length {}",
                    flat.len(), info.weights_len());

    let mut draft = info.clone();
    draft.name = format!("{}-draft{}", info.name, fold);
    draft.hidden = g;
    draft.artifacts = Default::default();
    draft.weights_file = String::new();
    let nl = info.weights_layout.len();
    draft.weights_layout = info
        .weights_layout
        .iter()
        .enumerate()
        .map(|(li, &(a, b))| {
            let a = if li == 0 { a } else { g };
            let b = if li == nl - 1 { b } else { g };
            (a, b)
        })
        .collect();

    let inv = 1.0f32 / fold as f32;
    let mut out = Vec::with_capacity(draft.weights_len());
    let mut src = 0usize;
    for (li, &(n_in, n_out)) in info.weights_layout.iter().enumerate() {
        let w = &flat[src..src + n_in * n_out];
        let b = &flat[src + n_in * n_out..src + n_in * n_out + n_out];
        src += n_in * n_out + n_out;
        let (first, last) = (li == 0, li == nl - 1);
        if last {
            // (H, d): sum over in-groups; bias unchanged
            for gi in 0..g {
                for o in 0..n_out {
                    let mut s = 0.0f32;
                    for i in gi * fold..(gi + 1) * fold {
                        s += w[i * n_out + o];
                    }
                    out.push(s);
                }
            }
            out.extend_from_slice(b);
        } else if first {
            // (n_in, H): mean over out-groups
            for i in 0..n_in {
                for go in 0..g {
                    let mut s = 0.0f32;
                    for o in go * fold..(go + 1) * fold {
                        s += w[i * n_out + o];
                    }
                    out.push(s * inv);
                }
            }
            for go in 0..g {
                let mut s = 0.0f32;
                for o in go * fold..(go + 1) * fold {
                    s += b[o];
                }
                out.push(s * inv);
            }
        } else {
            // (H, H): sum over in-group of the mean over out-group
            for gi in 0..g {
                for go in 0..g {
                    let mut s = 0.0f32;
                    for i in gi * fold..(gi + 1) * fold {
                        for o in go * fold..(go + 1) * fold {
                            s += w[i * n_out + o];
                        }
                    }
                    out.push(s * inv);
                }
            }
            for go in 0..g {
                let mut s = 0.0f32;
                for o in go * fold..(go + 1) * fold {
                    s += b[o];
                }
                out.push(s * inv);
            }
        }
    }
    debug_assert_eq!(out.len(), draft.weights_len());
    Ok((draft, out))
}

/// splitmix64-style hash to a deterministic value in (-0.5, 0.5).
fn unit(seed: u64, tag: u64) -> f32 {
    let mut z = seed
        .wrapping_add(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(tag.wrapping_mul(0xBF58_476D_1CE4_E5B9));
    z ^= z >> 30;
    z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^= z >> 27;
    z = z.wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    ((z >> 40) as f32 / (1u64 << 24) as f32) - 0.5
}

fn tag(layer: usize, a: usize, b: usize) -> u64 {
    ((layer as u64) << 48) ^ ((a as u64) << 24) ^ b as u64
}

/// Deterministically synthesize target weights whose intra-group
/// variance is controlled by `jitter`: at `jitter == 0` every weight is
/// exactly group-constant w.r.t. `fold`-sized hidden groups, so
/// [`distill_draft`] reproduces the target function up to f32
/// summation-order rounding; growing `jitter` degrades the draft
/// smoothly (the accept-rate knob for tests and the Pareto bench).
pub fn synth_group_constant(info: &VariantInfo, fold: usize, jitter: f32,
                            seed: u64) -> Result<Vec<f32>> {
    let _ = check_fold(info, fold)?;
    let nl = info.weights_layout.len();
    let scale = 0.4f32;
    let mut out = Vec::with_capacity(info.weights_len());
    for (li, &(n_in, n_out)) in info.weights_layout.iter().enumerate() {
        let (first, last) = (li == 0, li == nl - 1);
        for i in 0..n_in {
            for o in 0..n_out {
                // group-constant base: input layer keys on (i, group(o)),
                // hidden blocks on (group(i), group(o)), output layer is
                // free (exactness needs no structure there)
                let base = if last {
                    tag(li, i, o)
                } else if first {
                    tag(li, i, o / fold)
                } else {
                    tag(li, i / fold, o / fold)
                };
                let mut v = scale * unit(seed, base);
                if jitter > 0.0 {
                    v += jitter * unit(seed ^ 0xD1F7, tag(li, i, o + 1));
                }
                out.push(v);
            }
        }
        for o in 0..n_out {
            let base = if last { tag(li, n_in, o) } else { tag(li, n_in, o / fold) };
            let mut v = scale * unit(seed, base ^ 0xB1A5);
            if jitter > 0.0 {
                v += jitter * unit(seed ^ 0xD1F7, tag(li, n_in, o + 1) ^ 0xB1A5);
            }
            out.push(v);
        }
    }
    debug_assert_eq!(out.len(), info.weights_len());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{DenoiseModel, NativeMlp};

    fn probe(model: &dyn DenoiseModel, t: usize) -> Vec<f64> {
        let d = model.dim();
        let y: Vec<f64> =
            (0..d).map(|i| 0.3 * (i as f64 + 1.0) - 0.5).collect();
        let mut out = vec![0.0; d];
        model.denoise_one(&y, t, &[], &mut out).unwrap();
        out
    }

    #[test]
    fn distill_is_exact_on_group_constant_weights() {
        let info = VariantInfo::toy("dtgt", 3, 0, 24, 2, 12);
        let flat = synth_group_constant(&info, 4, 0.0, 9).unwrap();
        let (dinfo, dflat) = distill_draft(&info, &flat, 4).unwrap();
        assert_eq!(dinfo.hidden, 6);
        assert_eq!(dinfo.name, "dtgt-draft4");
        assert_eq!(dflat.len(), dinfo.weights_len());
        let target = NativeMlp::from_flat(&info, &flat).unwrap();
        let draft = NativeMlp::from_flat(&dinfo, &dflat).unwrap();
        for t in [1usize, 6, 12] {
            let a = probe(target.as_ref(), t);
            let b = probe(draft.as_ref(), t);
            for (x, y) in a.iter().zip(&b) {
                // summation-order f32 rounding only
                assert!((x - y).abs() < 1e-3,
                        "t={t}: target {x} vs draft {y}");
            }
        }
    }

    #[test]
    fn jitter_degrades_the_draft_smoothly() {
        let info = VariantInfo::toy("djit", 2, 0, 16, 1, 10);
        let mut errs = Vec::new();
        for jitter in [0.0f32, 0.05, 0.3] {
            let flat = synth_group_constant(&info, 4, jitter, 5).unwrap();
            let (dinfo, dflat) = distill_draft(&info, &flat, 4).unwrap();
            let target = NativeMlp::from_flat(&info, &flat).unwrap();
            let draft = NativeMlp::from_flat(&dinfo, &dflat).unwrap();
            let a = probe(target.as_ref(), 5);
            let b = probe(draft.as_ref(), 5);
            let err: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
            assert!(err.is_finite());
            errs.push(err);
        }
        assert!(errs[0] < 1e-3, "jitter=0 not exact: {}", errs[0]);
        assert!(errs[2] > errs[0],
                "jitter did not degrade the draft: {errs:?}");
    }

    #[test]
    fn distill_rejects_bad_folds() {
        let info = VariantInfo::toy("dbad", 2, 0, 24, 1, 10);
        let flat = vec![0.0f32; info.weights_len()];
        assert!(distill_draft(&info, &flat, 0).is_err());
        assert!(distill_draft(&info, &flat, 5).is_err());
        assert!(distill_draft(&info, &flat[..10], 4).is_err());
    }

    #[test]
    fn draft_keeps_dims_and_schedule() {
        let info = VariantInfo::toy("dkeep", 4, 2, 32, 2, 20);
        let flat = synth_group_constant(&info, 8, 0.1, 1).unwrap();
        let (dinfo, _) = distill_draft(&info, &flat, 8).unwrap();
        assert_eq!((dinfo.d, dinfo.cond_dim, dinfo.k_steps), (4, 2, 20));
        assert_eq!(dinfo.hidden, 4);
        assert_eq!(dinfo.abar, info.abar);
        assert!(dinfo.artifacts.is_empty());
        assert_eq!(dinfo.weights_layout.first().unwrap().0,
                   info.weights_layout.first().unwrap().0);
        assert_eq!(dinfo.weights_layout.last().unwrap().1, 4);
    }
}
