//! ParaDiGMS-style Picard iteration baseline (Shih et al., 2024).
//!
//! The paper's main prior-work comparison: break the sequential chain
//! with a sliding-window fixed-point iteration. Writing the DDPM update
//! in increment form Delta_j(y) = (c2_j - 1) y + c1_j x0hat(y, j+1)
//! + sigma_j xi_j, a Picard sweep updates the whole window from the
//! previous iterate *in one parallel round of model calls*:
//!
//!   y_{j+1}^{new} = y_a + sum_{l = a..j} Delta_l(y_l^{old})
//!
//! The window slides past entries whose update moved less than `tol`
//! (per-coordinate RMS). Unlike ASD this leaves a tunable bias: tol > 0
//! trades sample quality for rounds — exactly the trade-off the paper
//! contrasts against (our ablation bench sweeps it).

use std::sync::Arc;

use anyhow::Result;

use crate::ddpm::NoiseStreams;
use crate::model::{DenoiseModel, ParallelModel};
use crate::runtime::pool::PoolConfig;
use crate::sampler::{ArenaSpan, DenoiseDemand, RoundArena, RoundExec,
                     SamplerPoll, StepSampler};

#[derive(Debug, Clone, Copy)]
pub struct PicardConfig {
    /// sliding window size (paper's "parallel degree")
    pub window: usize,
    /// convergence tolerance (per-coordinate RMS change)
    pub tol: f64,
    /// hard cap on sweeps per window position (safety)
    pub max_sweeps: usize,
    /// sharded execution of each window sweep's batched model call on
    /// the global worker pool (bit-transparent; default inline)
    pub pool: PoolConfig,
}

impl Default for PicardConfig {
    fn default() -> PicardConfig {
        PicardConfig {
            window: 16,
            tol: 1e-3,
            max_sweeps: 1000,
            pool: PoolConfig::default(),
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct PicardStats {
    pub model_calls: usize,
    pub parallel_rounds: usize,
    pub sweeps: usize,
}

pub struct PicardSampler {
    pub model: Arc<dyn DenoiseModel>,
    pub config: PicardConfig,
}

impl PicardSampler {
    pub fn new(model: Arc<dyn DenoiseModel>, config: PicardConfig) -> Self {
        let model = ParallelModel::wrap(model, config.pool);
        PicardSampler { model, config }
    }

    /// Sample with explicit noise; same randomness contract as the other
    /// samplers (xi row j drives transition j+1 -> j). Clones the
    /// streams for the machine; `sample` hands its own over copy-free.
    pub fn sample_with_noise(&self, noise: &NoiseStreams, cond: &[f64])
                             -> Result<(Vec<f64>, PicardStats)> {
        self.sample_owned_noise(noise.clone(), cond)
    }

    pub fn sample(&self, seed: u64, cond: &[f64]) -> Result<(Vec<f64>, PicardStats)> {
        let noise = NoiseStreams::draw(seed, 0, self.model.k_steps(),
                                       self.model.dim());
        self.sample_owned_noise(noise, cond)
    }

    fn sample_owned_noise(&self, noise: NoiseStreams, cond: &[f64])
                          -> Result<(Vec<f64>, PicardStats)> {
        let mut machine = PicardStepMachine::new(
            self.model.clone(), self.config.window, self.config.tol,
            self.config.max_sweeps, noise, cond)?;
        let y = crate::sampler::drive(&mut machine, &self.model,
                                      self.config.pool)?;
        Ok((y, machine.into_stats()))
    }
}

/// Picard iteration as a poll/resume state machine: each demand is one
/// sliding-window sweep (`w_eff` rows, one parallel round); `resume`
/// applies the Picard update and either stages the next sweep or slides
/// the window. Bit-identical to the closed-loop sampler it replaced.
pub struct PicardStepMachine {
    model: Arc<dyn DenoiseModel>,
    w: usize,
    tol: f64,
    max_sweeps: usize,
    noise: NoiseStreams,
    // iterates y[pos] approximates y at DDPM index (k - done - pos - 1);
    // `base` is the converged prefix head at index k - done.
    base: Vec<f64>,
    done: usize,
    ys: Vec<f64>,
    new_ys: Vec<f64>,
    sweeps_here: usize,
    // staged demand: previous iterates of the window transitions
    eval_in: Vec<f64>,
    ts: Vec<f64>,
    cond_rows: Vec<f64>,
    acc: Vec<f64>,
    finished: bool,
    /// whether `eval_in`/`ts` hold the current sweep demand. Staging is
    /// deferred to `poll` so the arena path (`poll_into`) writes sweep
    /// rows straight from the iterates into the arena instead.
    staged: bool,
    stats: PicardStats,
}

impl PicardStepMachine {
    pub fn new(model: Arc<dyn DenoiseModel>, window: usize, tol: f64,
               max_sweeps: usize, noise: NoiseStreams, cond: &[f64])
               -> Result<PicardStepMachine> {
        anyhow::ensure!(cond.len() == model.cond_dim(),
                        "conditioning length {} != cond_dim {}",
                        cond.len(), model.cond_dim());
        // window = 0 would stage empty sweeps and underflow at the
        // window slide; reject it up front (a clean per-request error,
        // not a worker-killing panic)
        anyhow::ensure!(window >= 1, "Picard window must be >= 1");
        let d = model.dim();
        let k = model.k_steps();
        let c_dim = model.cond_dim();
        let w = window.min(k);
        let base = noise.y_k.clone();
        let mut ys = vec![0.0; w * d];
        // initial guess: copy base forward (cheap, no model calls)
        for pos in 0..w {
            ys[pos * d..(pos + 1) * d].copy_from_slice(&base);
        }
        let mut cond_rows = vec![0.0; w * cond.len().max(1)];
        // conditioning rows never change across sweeps: fill once
        if c_dim > 0 {
            for pos in 0..w {
                cond_rows[pos * c_dim..(pos + 1) * c_dim]
                    .copy_from_slice(cond);
            }
        }
        let mut m = PicardStepMachine {
            w,
            tol,
            max_sweeps,
            base,
            done: 0,
            ys,
            new_ys: vec![0.0; w * d],
            sweeps_here: 0,
            eval_in: vec![0.0; w * d],
            ts: vec![0.0; w],
            cond_rows,
            acc: vec![0.0; d],
            finished: k == 0,
            staged: false,
            noise,
            stats: PicardStats::default(),
            model,
        };
        Ok(m)
    }

    pub fn stats(&self) -> &PicardStats {
        &self.stats
    }

    pub fn into_stats(self) -> PicardStats {
        self.stats
    }

    fn w_eff(&self) -> usize {
        self.w.min(self.model.k_steps() - self.done)
    }

    /// Write the next sweep's demand — the *previous* iterate of every
    /// window transition idx -> idx-1 — into arbitrary target slices
    /// (sized exactly `w_eff`): the arena's reserved row range or the
    /// internal staging buffers.
    fn write_sweep_rows(&self, w_eff: usize, ys: &mut [f64],
                        ts: &mut [f64]) {
        let d = self.model.dim();
        let k = self.model.k_steps();
        for pos in 0..w_eff {
            let idx = k - self.done - pos; // DDPM index of the iterate
            let src: &[f64] = if pos == 0 {
                &self.base
            } else {
                &self.ys[(pos - 1) * d..pos * d]
            };
            ys[pos * d..(pos + 1) * d].copy_from_slice(src);
            ts[pos] = idx as f64;
        }
    }

    /// Compatibility staging for the slice-based `poll`.
    fn stage_sweep(&mut self) {
        let d = self.model.dim();
        let w_eff = self.w_eff();
        let mut ys = std::mem::take(&mut self.eval_in);
        let mut ts = std::mem::take(&mut self.ts);
        self.write_sweep_rows(w_eff, &mut ys[..w_eff * d],
                              &mut ts[..w_eff]);
        self.eval_in = ys;
        self.ts = ts;
        self.staged = true;
    }
}

impl StepSampler for PicardStepMachine {
    fn poll(&mut self) -> Result<SamplerPoll<'_>> {
        if self.finished {
            return Ok(SamplerPoll::Done(&self.base));
        }
        if !self.staged {
            self.stage_sweep();
        }
        let d = self.model.dim();
        let c_dim = self.model.cond_dim();
        let w_eff = self.w_eff();
        Ok(SamplerPoll::Demand(DenoiseDemand {
            ys: &self.eval_in[..w_eff * d],
            ts: &self.ts[..w_eff],
            cond: &self.cond_rows[..w_eff * c_dim],
            n: w_eff,
        }))
    }

    /// Arena path: stage the sweep rows straight into the arena's
    /// reserved row range (internal staging buffers bypassed).
    fn poll_into(&mut self, arena: &mut RoundArena)
                 -> Result<Option<ArenaSpan>> {
        if self.finished {
            return Ok(None);
        }
        let c_dim = self.model.cond_dim();
        let w_eff = self.w_eff();
        let (span, rows) = arena.reserve(w_eff);
        self.write_sweep_rows(w_eff, rows.ys, rows.ts);
        rows.cond.copy_from_slice(&self.cond_rows[..w_eff * c_dim]);
        Ok(Some(span))
    }

    fn resume(&mut self, x0: &[f64], _exec: RoundExec) -> Result<()> {
        anyhow::ensure!(!self.finished, "resume after Done");
        let d = self.model.dim();
        let k = self.model.k_steps();
        let w_eff = self.w_eff();
        anyhow::ensure!(x0.len() == w_eff * d,
                        "sweep rows length {} != {}", x0.len(), w_eff * d);
        self.sweeps_here += 1;
        self.stats.sweeps += 1;
        self.stats.model_calls += w_eff;
        self.stats.parallel_rounds += 1;

        let model = self.model.clone();
        let sched = model.schedule();
        // Picard update: accumulate increments from the window head
        self.acc.copy_from_slice(&self.base);
        let mut max_change = 0.0f64;
        for pos in 0..w_eff {
            let idx = k - self.done - pos; // transition idx -> idx-1
            let row = idx - 1;
            let prev: &[f64] = if pos == 0 {
                &self.base
            } else {
                &self.ys[(pos - 1) * d..pos * d]
            };
            let xi = self.noise.xi_row(row, d);
            for i in 0..d {
                let drift = (sched.c2[row] - 1.0) * prev[i]
                    + sched.c1[row] * x0[pos * d + i]
                    + if sched.sigma[row] > 0.0 {
                        sched.sigma[row] * xi[i]
                    } else {
                        0.0
                    };
                self.acc[i] += drift;
            }
            let slice = &mut self.new_ys[pos * d..(pos + 1) * d];
            let mut change = 0.0;
            for i in 0..d {
                let delta = self.acc[i] - self.ys[pos * d + i];
                change += delta * delta;
                slice[i] = self.acc[i];
            }
            max_change = max_change.max((change / d as f64).sqrt());
        }
        std::mem::swap(&mut self.ys, &mut self.new_ys);

        if max_change < self.tol || self.sweeps_here >= self.max_sweeps {
            // slide: finalize the whole window (it converged under tol)
            self.base.copy_from_slice(&self.ys[(w_eff - 1) * d..w_eff * d]);
            self.done += w_eff;
            self.sweeps_here = 0;
            if self.done == k {
                self.finished = true;
                return Ok(());
            }
            let w_next = self.w.min(k - self.done);
            for pos in 0..w_next {
                self.ys[pos * d..(pos + 1) * d].copy_from_slice(&self.base);
            }
        }
        // the next demand is staged lazily by poll / poll_into
        self.staged = false;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ddpm::SequentialSampler;
    use crate::model::{Gmm, GmmDdpmOracle};

    #[test]
    fn tight_tolerance_matches_sequential() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 40, false);
        let seq = SequentialSampler::new(oracle.clone());
        let pic = PicardSampler::new(
            oracle,
            PicardConfig { window: 8, tol: 1e-10, max_sweeps: 500,
                           ..Default::default() });
        for seed in 0..5 {
            let noise = NoiseStreams::draw(seed, 0, 40, 2);
            let (a, _) = seq.sample_with_noise(&noise, &[]).unwrap();
            let (b, stats) = pic.sample_with_noise(&noise, &[]).unwrap();
            for i in 0..2 {
                assert!((a[i] - b[i]).abs() < 1e-5,
                        "seed {seed}: {a:?} vs {b:?} ({stats:?})");
            }
        }
    }

    #[test]
    fn loose_tolerance_saves_rounds_but_leaves_error() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 60, false);
        let tight = PicardSampler::new(
            oracle.clone(),
            PicardConfig { window: 12, tol: 1e-9, max_sweeps: 500,
                           ..Default::default() });
        let loose = PicardSampler::new(
            oracle,
            PicardConfig { window: 12, tol: 0.05, max_sweeps: 500,
                           ..Default::default() });
        let mut rounds_tight = 0;
        let mut rounds_loose = 0;
        let mut err = 0.0;
        for seed in 0..5 {
            let noise = NoiseStreams::draw(seed, 0, 60, 2);
            let (a, st) = tight.sample_with_noise(&noise, &[]).unwrap();
            let (b, sl) = loose.sample_with_noise(&noise, &[]).unwrap();
            rounds_tight += st.parallel_rounds;
            rounds_loose += sl.parallel_rounds;
            err += crate::math::vec_ops::dist(&a, &b);
        }
        assert!(rounds_loose < rounds_tight);
        assert!(err > 1e-6, "loose Picard should leave some bias");
    }

    #[test]
    fn zero_window_is_a_clean_error_not_a_panic() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 10, false);
        let pic = PicardSampler::new(
            oracle, PicardConfig { window: 0, ..Default::default() });
        let err = pic.sample(1, &[]).unwrap_err();
        assert!(err.to_string().contains("window"), "{err:#}");
    }

    #[test]
    fn rounds_bounded_by_k_times_sweeps() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 30, false);
        let pic = PicardSampler::new(
            oracle, PicardConfig { window: 6, tol: 1e-6, max_sweeps: 100,
                                   ..Default::default() });
        let (_, stats) = pic.sample(3, &[]).unwrap();
        assert!(stats.parallel_rounds >= 5); // at least one sweep per window
        assert!(stats.model_calls <= 30 * 100);
    }
}
