//! ParaDiGMS-style Picard iteration baseline (Shih et al., 2024).
//!
//! The paper's main prior-work comparison: break the sequential chain
//! with a sliding-window fixed-point iteration. Writing the DDPM update
//! in increment form Delta_j(y) = (c2_j - 1) y + c1_j x0hat(y, j+1)
//! + sigma_j xi_j, a Picard sweep updates the whole window from the
//! previous iterate *in one parallel round of model calls*:
//!
//!   y_{j+1}^{new} = y_a + sum_{l = a..j} Delta_l(y_l^{old})
//!
//! The window slides past entries whose update moved less than `tol`
//! (per-coordinate RMS). Unlike ASD this leaves a tunable bias: tol > 0
//! trades sample quality for rounds — exactly the trade-off the paper
//! contrasts against (our ablation bench sweeps it).

use std::sync::Arc;

use anyhow::Result;

use crate::ddpm::NoiseStreams;
use crate::model::{DenoiseModel, ParallelModel};
use crate::runtime::pool::PoolConfig;

pub struct PicardConfig {
    /// sliding window size (paper's "parallel degree")
    pub window: usize,
    /// convergence tolerance (per-coordinate RMS change)
    pub tol: f64,
    /// hard cap on sweeps per window position (safety)
    pub max_sweeps: usize,
    /// sharded execution of each window sweep's batched model call on
    /// the global worker pool (bit-transparent; default inline)
    pub pool: PoolConfig,
}

impl Default for PicardConfig {
    fn default() -> PicardConfig {
        PicardConfig {
            window: 16,
            tol: 1e-3,
            max_sweeps: 1000,
            pool: PoolConfig::default(),
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct PicardStats {
    pub model_calls: usize,
    pub parallel_rounds: usize,
    pub sweeps: usize,
}

pub struct PicardSampler {
    pub model: Arc<dyn DenoiseModel>,
    pub config: PicardConfig,
}

impl PicardSampler {
    pub fn new(model: Arc<dyn DenoiseModel>, config: PicardConfig) -> Self {
        let model = ParallelModel::wrap(model, config.pool);
        PicardSampler { model, config }
    }

    /// Sample with explicit noise; same randomness contract as the other
    /// samplers (xi row j drives transition j+1 -> j).
    pub fn sample_with_noise(&self, noise: &NoiseStreams, cond: &[f64])
                             -> Result<(Vec<f64>, PicardStats)> {
        let d = self.model.dim();
        let k = self.model.k_steps();
        let model = self.model.clone();
        let sched = model.schedule(); // borrow, not clone
        let mut stats = PicardStats::default();

        // iterates y[pos] approximates y at DDPM index (k - pos);
        // pos 0 is the known start y_K.
        // We process a sliding window of `window` unknown entries.
        let w = self.config.window.min(k);
        let mut base = noise.y_k.clone(); // converged prefix head: index k - done
        let mut done = 0usize; // transitions finalized
        // window state: guesses for y at indices k-done-1 .. k-done-w
        let mut ys = vec![0.0; w * d];
        let mut new_ys = vec![0.0; w * d];
        // initialize guesses with the frozen-drift chain from base
        let mut ts = vec![0.0; w];
        let mut x0 = vec![0.0; w * d];
        let mut cond_rows = vec![0.0; w * cond.len().max(1)];
        let c_dim = self.model.cond_dim();

        // initial guess: copy base forward (cheap, no model calls)
        for pos in 0..w {
            ys[pos * d..(pos + 1) * d].copy_from_slice(&base);
        }
        // conditioning rows never change across sweeps: fill once
        if c_dim > 0 {
            for pos in 0..w {
                cond_rows[pos * c_dim..(pos + 1) * c_dim]
                    .copy_from_slice(cond);
            }
        }
        // sweep scratch, allocated once per sample (the sweep loop
        // itself is allocation-free)
        let mut eval_in = vec![0.0; w * d];
        let mut acc = vec![0.0; d];

        while done < k {
            let w_eff = w.min(k - done);
            let mut sweeps_here = 0usize;
            loop {
                sweeps_here += 1;
                stats.sweeps += 1;
                // one parallel round: evaluate x0hat at the *previous*
                // iterate of every window transition idx -> idx-1
                for pos in 0..w_eff {
                    let idx = k - done - pos; // DDPM index of the iterate
                    let src: &[f64] = if pos == 0 {
                        &base
                    } else {
                        &ys[(pos - 1) * d..pos * d]
                    };
                    eval_in[pos * d..(pos + 1) * d].copy_from_slice(src);
                    ts[pos] = idx as f64;
                }
                self.model.denoise_batch(&eval_in[..w_eff * d],
                                         &ts[..w_eff],
                                         &cond_rows[..w_eff * c_dim],
                                         w_eff, &mut x0[..w_eff * d])?;
                stats.model_calls += w_eff;
                stats.parallel_rounds += 1;

                // Picard update: accumulate increments from the window head
                acc.copy_from_slice(&base);
                let mut max_change = 0.0f64;
                for pos in 0..w_eff {
                    let idx = k - done - pos; // transition idx -> idx-1
                    let row = idx - 1;
                    let prev: &[f64] = if pos == 0 {
                        &base
                    } else {
                        &ys[(pos - 1) * d..pos * d]
                    };
                    let xi = noise.xi_row(row, d);
                    for i in 0..d {
                        let drift = (sched.c2[row] - 1.0) * prev[i]
                            + sched.c1[row] * x0[pos * d + i]
                            + if sched.sigma[row] > 0.0 {
                                sched.sigma[row] * xi[i]
                            } else {
                                0.0
                            };
                        acc[i] += drift;
                    }
                    let slice = &mut new_ys[pos * d..(pos + 1) * d];
                    let mut change = 0.0;
                    for i in 0..d {
                        let delta = acc[i] - ys[pos * d + i];
                        change += delta * delta;
                        slice[i] = acc[i];
                    }
                    max_change = max_change.max((change / d as f64).sqrt());
                }
                std::mem::swap(&mut ys, &mut new_ys);

                if max_change < self.config.tol
                    || sweeps_here >= self.config.max_sweeps
                {
                    break;
                }
            }
            // slide: finalize the whole window (it converged under tol)
            let w_eff = w.min(k - done);
            base.copy_from_slice(&ys[(w_eff - 1) * d..w_eff * d]);
            done += w_eff;
            for pos in 0..w.min(k - done) {
                ys[pos * d..(pos + 1) * d].copy_from_slice(&base);
            }
        }
        Ok((base, stats))
    }

    pub fn sample(&self, seed: u64, cond: &[f64]) -> Result<(Vec<f64>, PicardStats)> {
        let noise = NoiseStreams::draw(seed, 0, self.model.k_steps(),
                                       self.model.dim());
        self.sample_with_noise(&noise, cond)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ddpm::SequentialSampler;
    use crate::model::{Gmm, GmmDdpmOracle};

    #[test]
    fn tight_tolerance_matches_sequential() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 40, false);
        let seq = SequentialSampler::new(oracle.clone());
        let pic = PicardSampler::new(
            oracle,
            PicardConfig { window: 8, tol: 1e-10, max_sweeps: 500,
                           ..Default::default() });
        for seed in 0..5 {
            let noise = NoiseStreams::draw(seed, 0, 40, 2);
            let (a, _) = seq.sample_with_noise(&noise, &[]).unwrap();
            let (b, stats) = pic.sample_with_noise(&noise, &[]).unwrap();
            for i in 0..2 {
                assert!((a[i] - b[i]).abs() < 1e-5,
                        "seed {seed}: {a:?} vs {b:?} ({stats:?})");
            }
        }
    }

    #[test]
    fn loose_tolerance_saves_rounds_but_leaves_error() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 60, false);
        let tight = PicardSampler::new(
            oracle.clone(),
            PicardConfig { window: 12, tol: 1e-9, max_sweeps: 500,
                           ..Default::default() });
        let loose = PicardSampler::new(
            oracle,
            PicardConfig { window: 12, tol: 0.05, max_sweeps: 500,
                           ..Default::default() });
        let mut rounds_tight = 0;
        let mut rounds_loose = 0;
        let mut err = 0.0;
        for seed in 0..5 {
            let noise = NoiseStreams::draw(seed, 0, 60, 2);
            let (a, st) = tight.sample_with_noise(&noise, &[]).unwrap();
            let (b, sl) = loose.sample_with_noise(&noise, &[]).unwrap();
            rounds_tight += st.parallel_rounds;
            rounds_loose += sl.parallel_rounds;
            err += crate::math::vec_ops::dist(&a, &b);
        }
        assert!(rounds_loose < rounds_tight);
        assert!(err > 1e-6, "loose Picard should leave some bias");
    }

    #[test]
    fn rounds_bounded_by_k_times_sweeps() {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), 30, false);
        let pic = PicardSampler::new(
            oracle, PicardConfig { window: 6, tol: 1e-6, max_sweeps: 100,
                                   ..Default::default() });
        let (_, stats) = pic.sample(3, &[]).unwrap();
        assert!(stats.parallel_rounds >= 5); // at least one sweep per window
        assert!(stats.model_calls <= 30 * 100);
    }
}
