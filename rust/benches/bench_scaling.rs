//! Theorem 4 — adaptive-complexity scaling: parallel rounds vs K on the
//! SL process with the analytic GMM oracle. Expected log-log slope ~1/3
//! at eta ~ T/K (sequential = 1.0).
//!
//! Run: cargo bench --bench bench_scaling

use asd::asd::SlAsd;
use asd::model::{Gmm, GmmSlOracle};
use asd::schedule::SlGrid;

fn main() {
    let t_max = 200.0;
    let samples = 4u64;
    println!("=== Thm 4 — parallel rounds vs K (SL-native ASD, analytic \
              GMM oracle, T={t_max}) ===\n");
    let oracle = GmmSlOracle { gmm: Gmm::circle_2d() };
    println!("{:>6} {:>7} {:>10} {:>12} {:>14}", "K", "theta", "rounds",
             "vs seq (K)", "rounds/K^(2/3)");
    let mut pts = Vec::new();
    for k in [128usize, 256, 512, 1024, 2048, 4096] {
        let eta = t_max / k as f64;
        let theta = ((k as f64 / (2.0 * eta)).powf(1.0 / 3.0)).ceil() as usize;
        let grid = SlGrid::uniform(t_max, k);
        let asd = SlAsd { oracle: &oracle, grid: &grid, theta: theta.max(2) };
        let mut rounds = 0usize;
        for s in 0..samples {
            rounds += asd.sample(s).1.parallel_rounds;
        }
        let mean = rounds as f64 / samples as f64;
        pts.push(((k as f64).ln(), mean.ln()));
        println!("{:>6} {:>7} {:>10.1} {:>12.2}x {:>14.2}", k, theta, mean,
                 k as f64 / mean, mean / (k as f64).powf(2.0 / 3.0));
    }
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    println!("\nlog-log slope = {slope:.3} (theory ~0.33 in this \
              parametrization; sequential = 1.0)");
    assert!(slope < 0.7, "scaling should be clearly sublinear");
}
