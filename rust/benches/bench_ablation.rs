//! Ablations called out in DESIGN.md §9:
//!  1. ASD vs the Picard/ParaDiGMS baseline: rounds AND bias (ASD is
//!     error-free; Picard trades error for rounds via its tolerance).
//!  2. eval_tail on/off (proposal chaining from the verify round).
//!  3. fixed theta vs the adaptive-theta controller.
//!
//! Run: cargo bench --bench bench_ablation

use std::sync::Arc;

use asd::asd::{AdaptiveTheta, AsdConfig, AsdEngine, KernelBackend};
use asd::ddpm::{NoiseStreams, SequentialSampler};
use asd::model::{DenoiseModel, Gmm, GmmDdpmOracle};
use asd::picard::{PicardConfig, PicardSampler};

fn main() -> anyhow::Result<()> {
    let k = 200;
    let n = 12u64;
    let model: Arc<dyn DenoiseModel> =
        GmmDdpmOracle::new(Gmm::circle_2d(), k, false);

    // --- 1. ASD vs Picard ---------------------------------------------
    println!("=== Ablation 1 — ASD vs Picard/ParaDiGMS (K={k}, analytic \
              oracle, n={n}) ===");
    println!("{:<22} {:>10} {:>14} {:>16}", "method", "rounds",
             "calls", "bias vs exact");
    let seq = SequentialSampler::new(model.clone());
    let mut engine = AsdEngine::new(
        model.clone(),
        AsdConfig { theta: 8, eval_tail: true, backend: KernelBackend::Native,
                    ..Default::default() });
    let mut asd_rounds = 0.0;
    let mut asd_calls = 0.0;
    let mut asd_bias = 0.0;
    for s in 0..n {
        let noise = NoiseStreams::draw(s, 0, k, 2);
        let (_y_seq, _) = seq.sample_with_noise(&noise, &[])?;
        let out = engine.sample_with_noise(&noise, &[])?;
        asd_rounds += out.stats.parallel_rounds as f64;
        asd_calls += out.stats.model_calls as f64;
        // "bias": ASD is distributionally exact; per-trace it may differ
        // from the sequential trace only through rejected-step reflections
        // (both are exact samples). Report radial error vs the target
        // radius instead, which is the real quality measure:
        asd_bias += ((out.y0[0].powi(2) + out.y0[1].powi(2)).sqrt() - 1.5).abs();
    }
    println!("{:<22} {:>10.1} {:>14.1} {:>16.4}", "ASD-8 (exact)",
             asd_rounds / n as f64, asd_calls / n as f64,
             asd_bias / n as f64);

    for (label, tol) in [("Picard tol=1e-8", 1e-8), ("Picard tol=1e-3", 1e-3),
                         ("Picard tol=3e-2", 3e-2)] {
        let pic = PicardSampler::new(
            model.clone(),
            PicardConfig { window: 16, tol, max_sweeps: 500,
                           ..Default::default() });
        let mut rounds = 0.0;
        let mut calls = 0.0;
        let mut bias = 0.0;
        for s in 0..n {
            let noise = NoiseStreams::draw(s, 0, k, 2);
            let (y_exact, _) = seq.sample_with_noise(&noise, &[])?;
            let (y_pic, st) = pic.sample_with_noise(&noise, &[])?;
            rounds += st.parallel_rounds as f64;
            calls += st.model_calls as f64;
            bias += asd::math::vec_ops::dist(&y_exact, &y_pic);
        }
        println!("{:<22} {:>10.1} {:>14.1} {:>16.4}", label,
                 rounds / n as f64, calls / n as f64, bias / n as f64);
    }
    println!("(Picard bias is vs the exact sequential trace with shared \
              noise — the error the paper's Picard-based baselines leave; \
              ASD's column shows mean |radius - target|, its traces being \
              exact by Thm 3)\n");

    // --- 2. eval_tail ablation ------------------------------------------
    println!("=== Ablation 2 — proposal chaining (eval_tail) ===");
    println!("{:<22} {:>10} {:>14}", "config", "rounds", "calls");
    for (label, tail) in [("eval_tail=true", true), ("eval_tail=false", false)] {
        let mut e = AsdEngine::new(
            model.clone(),
            AsdConfig { theta: 8, eval_tail: tail,
                        backend: KernelBackend::Native,
                        ..Default::default() });
        let mut rounds = 0.0;
        let mut calls = 0.0;
        for s in 0..n {
            let out = e.sample(s)?;
            rounds += out.stats.parallel_rounds as f64;
            calls += out.stats.model_calls as f64;
        }
        println!("{:<22} {:>10.1} {:>14.1}", label, rounds / n as f64,
                 calls / n as f64);
    }
    println!();

    // --- 3. adaptive theta ----------------------------------------------
    println!("=== Ablation 3 — fixed vs adaptive theta ===");
    println!("{:<22} {:>10} {:>14} {:>12}", "config", "rounds", "calls",
             "final theta");
    for fixed in [2usize, 8, 32] {
        let mut e = AsdEngine::new(
            model.clone(),
            AsdConfig { theta: fixed, eval_tail: true,
                        backend: KernelBackend::Native,
                        ..Default::default() });
        let mut rounds = 0.0;
        let mut calls = 0.0;
        for s in 0..n {
            let out = e.sample(s)?;
            rounds += out.stats.parallel_rounds as f64;
            calls += out.stats.model_calls as f64;
        }
        println!("{:<22} {:>10.1} {:>14.1} {:>12}", format!("theta={fixed}"),
                 rounds / n as f64, calls / n as f64, "-");
    }
    // adaptive: re-tune theta between iterations using the controller
    let mut ctl = AdaptiveTheta::new(2, 32);
    let mut rounds = 0.0;
    let mut calls = 0.0;
    for s in 0..n {
        let mut e = AsdEngine::new(
            model.clone(),
            AsdConfig { theta: ctl.theta(), eval_tail: true,
                        backend: KernelBackend::Native,
                        ..Default::default() });
        let out = e.sample(s)?;
        ctl.observe(out.stats.accepted, out.stats.rejected);
        rounds += out.stats.parallel_rounds as f64;
        calls += out.stats.model_calls as f64;
    }
    println!("{:<22} {:>10.1} {:>14.1} {:>12}", "adaptive",
             rounds / n as f64, calls / n as f64, ctl.theta());
    Ok(())
}
