//! Fig 2 — ASD speedup over DDPM on the latent diffusion stand-in
//! (latent16, K=1000), theta sweep incl. infinity. Prints algorithmic +
//! wall-clock (measured 1-device and modeled 8-worker) speedups.
//!
//! Run: cargo bench --bench bench_fig2

use std::sync::Arc;

use asd::exp::latency::default_latency_model;
use asd::exp::quality::make_class_conds;
use asd::exp::{format_rows, sweep_thetas};
use asd::model::DenoiseModel;
use asd::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let n = 4;
    let rt = Runtime::load_default()?;
    let model = rt.model("latent16")?;
    model.warmup()?;
    let k = model.info.k_steps;
    let dyn_model: Arc<dyn DenoiseModel> = model.clone();

    let seq = asd::ddpm::SequentialSampler::new(dyn_model.clone());
    let (conds, _) = make_class_conds(&dyn_model, n);
    let t0 = std::time::Instant::now();
    seq.sample(0, &conds[0])?;
    let seq_wall = t0.elapsed().as_secs_f64();

    let latency = default_latency_model(&model, 8)?;
    let rows = sweep_thetas(dyn_model, &[2, 4, 6, 8, 0], n, seq_wall, 100,
                            Some(&conds), &latency)?;
    println!("=== Fig 2 — Speedup on Latent Diffusion Model (latent16, \
              K={k}, n={n}) ===");
    println!("paper shape: algorithmic speedup grows with theta and \
              saturates by theta=6-8; ASD-inf ~ upper bound; wall-clock \
              lags algorithmic due to transfer overhead\n");
    print!("{}", format_rows(k, &rows));
    println!("\nmeasured sequential wall: {:.1} ms/sample", seq_wall * 1e3);
    Ok(())
}
