//! Microbenchmarks of the hot-path components (feeds EXPERIMENTS.md
//! §Perf): PJRT dispatch per batch size, native GRS, proposal chain,
//! Philox throughput, JSON parse.
//!
//! Run: cargo bench --bench bench_micro

use asd::asd::grs_native;
use asd::ddpm::NoiseStreams;
use asd::model::DenoiseModel;
use asd::rng::Philox;
use asd::runtime::Runtime;
use asd::util::timer::bench;

fn main() -> anyhow::Result<()> {
    println!("=== Microbenchmarks (1-core CPU testbed) ===\n");

    // Philox throughput
    let mut rng = Philox::new(1, 0);
    let st = bench(3, 20, || {
        let mut acc = 0.0;
        for _ in 0..100_000 {
            acc += rng.normal();
        }
        std::hint::black_box(acc);
    });
    println!("{}", st.row("philox normal x100k"));

    // GRS native
    let d = 224;
    let mut g = Philox::new(2, 0);
    let xi: Vec<f64> = (0..d).map(|_| g.normal()).collect();
    let m_hat: Vec<f64> = (0..d).map(|_| g.normal()).collect();
    let m: Vec<f64> = m_hat.iter().map(|x| x + 0.1).collect();
    let mut z = vec![0.0; d];
    let mut v = vec![0.0; d];
    let st = bench(10, 50, || {
        for i in 0..1000 {
            let u = (i as f64 + 0.5) / 1000.0;
            std::hint::black_box(grs_native(u, &xi, &m_hat, &m, 0.3,
                                            &mut z, &mut v));
        }
    });
    println!("{}", st.row("grs_native d=224 x1k"));

    // PJRT dispatch latency per batch size, per variant
    let rt = Runtime::load_default()?;
    for variant in ["gmm2d", "latent16", "pixel64", "policy_transport"] {
        let model = rt.model(variant)?;
        model.warmup()?;
        let d = model.info.d;
        let c = model.info.cond_dim;
        for b in [1usize, 8, 32] {
            let ys = vec![0.1; b * d];
            let ts = vec![(model.info.k_steps / 2) as f64; b];
            let cond = vec![0.0; b * c];
            let mut out = vec![0.0; b * d];
            model.denoise_batch(&ys, &ts, &cond, b, &mut out)?;
            let st = bench(3, 30, || {
                model.denoise_batch(&ys, &ts, &cond, b, &mut out).unwrap();
            });
            println!("{}", st.row(&format!("hlo denoise {variant} b={b}")));
        }
    }

    // NoiseStreams generation (per-request randomness setup)
    let st = bench(3, 30, || {
        std::hint::black_box(NoiseStreams::draw(7, 0, 1000, 64));
    });
    println!("{}", st.row("noise streams K=1000 d=64"));

    // JSON manifest parse
    let dir = asd::artifacts_dir();
    let text = std::fs::read_to_string(dir.join("manifest.json"))?;
    let st = bench(2, 10, || {
        std::hint::black_box(asd::util::Json::parse(&text).unwrap());
    });
    println!("{}", st.row(&format!("manifest.json parse ({} KB)",
                                   text.len() / 1024)));
    Ok(())
}
