//! Fig 5 — diffusion-policy speedup on the three manipulation tasks
//! (K=100, one simulated device, batched verification — the paper's
//! policy setup). Higher acceptance than images => bigger useful theta.
//!
//! Run: cargo bench --bench bench_fig5

use std::sync::Arc;

use asd::env::{rollout_policy, DiffusionPolicy, SamplerKind, TaskSpec};
use asd::model::DenoiseModel;
use asd::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let episodes = 2u64;
    let rt = Runtime::load_default()?;
    println!("=== Fig 5 — Speedup of diffusion policies (K=100, batched \
              1-device verification, {episodes} episodes/point) ===");
    println!("paper shape: acceptance is much higher than image models \
              => 6-7x algorithmic for ASD-inf, saturation near theta=20-24\n");
    for task in ["square", "transport", "toolhang"] {
        let model = rt.model(&format!("policy_{task}"))?;
        model.warmup()?;
        let dyn_model: Arc<dyn DenoiseModel> = model;
        let policy = DiffusionPolicy::new(dyn_model,
                                          TaskSpec::by_name(task).unwrap())?;
        let mut seq_rounds = 0.0;
        let mut seq_wall = 0.0;
        let mut plans = 0.0;
        for s in 0..episodes {
            let r = rollout_policy(&policy, SamplerKind::Sequential, s)?;
            seq_rounds += r.parallel_rounds as f64;
            seq_wall += r.wallclock_s;
            plans += r.plans as f64;
        }
        println!("[{task}] sequential: {:.0} rounds/plan, {:.1} ms/plan",
                 seq_rounds / plans, seq_wall / plans * 1e3);
        println!("{:<10} {:>12} {:>14} {:>13}", "method", "alg speedup",
                 "wall x (1dev)", "rounds/plan");
        for theta in [8usize, 12, 16, 20, 24, 0] {
            let mut rounds = 0.0;
            let mut wall = 0.0;
            let mut plans_a = 0.0;
            for s in 0..episodes {
                let r = rollout_policy(&policy, SamplerKind::Asd(theta), s)?;
                rounds += r.parallel_rounds as f64;
                wall += r.wallclock_s;
                plans_a += r.plans as f64;
            }
            let label = if theta == 0 { "ASD-inf".into() }
                        else { format!("ASD-{theta}") };
            println!("{:<10} {:>12.2} {:>14.2} {:>13.1}", label,
                     (seq_rounds / plans) / (rounds / plans_a),
                     (seq_wall / plans) / (wall / plans_a),
                     rounds / plans_a);
        }
        println!();
    }
    Ok(())
}
