//! Fig 4 — ASD speedup on the pixel diffusion stand-in (pixel64,
//! K=1000). The paper's narrative: per-call compute is cheaper than the
//! latent model while the transfer payload is larger, so the gap between
//! algorithmic and wall-clock speedup widens. The modeled column uses a
//! 10x higher per-float transfer cost, mirroring the paper's reported
//! 10x transfer overhead for the pixel model.
//!
//! Run: cargo bench --bench bench_fig4

use std::sync::Arc;

use asd::exp::latency::default_latency_model;
use asd::exp::{format_rows, sweep_thetas};
use asd::model::DenoiseModel;
use asd::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let n = 4;
    let rt = Runtime::load_default()?;
    let model = rt.model("pixel64")?;
    model.warmup()?;
    let k = model.info.k_steps;
    let dyn_model: Arc<dyn DenoiseModel> = model.clone();

    let seq = asd::ddpm::SequentialSampler::new(dyn_model.clone());
    let t0 = std::time::Instant::now();
    seq.sample(0, &[])?;
    let seq_wall = t0.elapsed().as_secs_f64();

    let mut latency = default_latency_model(&model, 8)?;
    latency.xfer_per_float *= 10.0; // paper: 10x transfer overhead (fp32 pixels)
    let rows = sweep_thetas(dyn_model, &[2, 4, 6, 8, 0], n, seq_wall, 200,
                            None, &latency)?;
    println!("=== Fig 4 — Speedup on Pixel Diffusion Model (pixel64, \
              K={k}, n={n}) ===");
    println!("paper shape: higher algorithmic speedup than the latent \
              model (up to ~3.1x) but a wider algorithmic/wall-clock gap\n");
    print!("{}", format_rows(k, &rows));
    println!("\nmeasured sequential wall: {:.1} ms/sample", seq_wall * 1e3);
    Ok(())
}
