//! Measured parallel-round execution: the native MLP's batched GEMM
//! forward vs its scalar reference, GEMM M-sharding on the worker
//! pool, and the ASD pool-size sweep (wall-clock next to algorithmic
//! rounds). Emits the machine-readable `BENCH_parallel.json` artifact
//! so the perf trajectory is tracked across PRs.
//!
//! Workloads:
//! * **native forward** — the default toy MLP variant (d=8, hidden=32,
//!   3 residual blocks, K=100 — the scale of the repo's real variants,
//!   where per-row libm exp/sin/cos and per-row scratch allocation
//!   dominate the row-at-a-time path); `denoise_batch` (GEMM pipeline
//!   + workspace + temb cache + vectorized SiLU) must beat
//!   `denoise_batch_ref` by >= 4x rows/s at B >= 64.
//! * **ASD sweep** — a wide random GMM oracle; outputs are asserted
//!   bit-identical across pool sizes (the pool buys wall-clock only).
//!
//! Run: cargo bench --bench bench_parallel

use std::sync::Arc;

use asd::coordinator::ServerConfig;
use asd::ddpm::BatchedSequentialSampler;
use asd::exp::serve_bench::{bench_coordinator, bench_coordinator_json,
                            format_coord_rows};
use asd::exp::speedup::{bench_parallel_json, format_pool_rows,
                        outputs_bit_identical, sweep_pool_sizes,
                        write_bench_json, ForwardBenchRow};
use asd::math::gemm::{gemm_bias_act, gemm_sharded, Epilogue};
use asd::model::{DenoiseModel, Gmm, GmmDdpmOracle, NativeMlp, VariantInfo,
                 Workspace};
use asd::runtime::pool::{default_threads, PoolConfig};
use asd::util::timer::bench;

/// The default toy variant: a realistically-shaped small denoiser.
fn toy_mlp(d: usize, hidden: usize, blocks: usize, k_steps: usize)
           -> Arc<NativeMlp> {
    let info = VariantInfo::toy("toy-bench", d, 0, hidden, blocks, k_steps);
    let flat: Vec<f32> = (0..info.weights_len())
        .map(|i| {
            let h = (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
            ((h % 2003) as f32 / 2003.0 - 0.5) * 0.2
        })
        .collect();
    NativeMlp::from_flat(&info, &flat).expect("toy variant")
}

fn main() -> anyhow::Result<()> {
    println!("=== Native GEMM forward + sharded worker pool \
              ({} pool threads available) ===\n", default_threads());

    // --- native MLP: GEMM pipeline vs scalar reference ----------------
    let d = 8usize;
    let (hidden, blocks, k_steps) = (32usize, 3usize, 100usize);
    let mlp = toy_mlp(d, hidden, blocks, k_steps);
    println!("[native MLP d={d} hidden={hidden} blocks={blocks}: \
              GEMM batch forward vs scalar ref]");
    let mut forward_rows: Vec<ForwardBenchRow> = Vec::new();
    let mut speedup_b64 = 0.0f64;
    for &b in &[1usize, 16, 64, 256] {
        let ys: Vec<f64> =
            (0..b * d).map(|i| (i as f64 * 0.37).sin()).collect();
        let ts: Vec<f64> = (0..b).map(|r| (1 + r % k_steps) as f64).collect();
        let mut out = vec![0.0; b * d];
        let mut ws = Workspace::new();
        let st_ref = bench(3, 20, || {
            mlp.denoise_batch_ref(&ys, &ts, &[], b, &mut out).unwrap();
        });
        let st_gemm = bench(3, 20, || {
            mlp.denoise_batch_with(&ys, &ts, &[], b, &mut out, &mut ws)
                .unwrap();
        });
        let r_ref = ForwardBenchRow::from_mean_s(
            "scalar_ref", b, 1, st_ref.mean_ms / 1e3);
        let r_gemm = ForwardBenchRow::from_mean_s(
            "gemm", b, 1, st_gemm.mean_ms / 1e3);
        let x = r_gemm.rows_per_s / r_ref.rows_per_s.max(1e-12);
        println!("B={b:<5} scalar_ref {:>12.0} rows/s ({:>8.0} ns/row)   \
                  gemm {:>12.0} rows/s ({:>8.0} ns/row)   {x:.2}x",
                 r_ref.rows_per_s, r_ref.ns_per_row,
                 r_gemm.rows_per_s, r_gemm.ns_per_row);
        if b == 64 {
            speedup_b64 = x;
        }
        forward_rows.push(r_ref);
        forward_rows.push(r_gemm);
    }
    // (the >= 4x floor is asserted at the very end, after
    // BENCH_parallel.json is written — a regression must not destroy
    // the artifact needed to diagnose it)
    println!("GEMM speedup at B=64: {speedup_b64:.2}x (floor: 4x)\n");

    // --- raw GEMM: M-sharding on the global pool ----------------------
    println!("[raw GEMM 256x256, B=256: M-sharded on the pool]");
    {
        let (m, n, k) = (256usize, 256usize, 256usize);
        let a: Vec<f32> =
            (0..m * k).map(|i| ((i % 601) as f32 / 601.0) - 0.5).collect();
        let w: Vec<f32> =
            (0..k * n).map(|i| ((i % 709) as f32 / 709.0) - 0.5).collect();
        let bias = vec![0.01f32; n];
        let mut c = vec![0.0f32; m * n];
        let mut base_ms = 0.0;
        for &shards in &[1usize, 2, 4, 8] {
            let st = bench(2, 10, || {
                gemm_sharded(m, n, k, &a, &w, Some(&bias), Epilogue::Silu,
                             None, &mut c, shards);
            });
            if shards == 1 {
                base_ms = st.mean_ms;
            }
            println!("{}  ({:.2}x vs serial)",
                     st.row(&format!("gemm_sharded shards={shards}")),
                     base_ms / st.mean_ms.max(1e-12));
            // distinct backend label: these rows measure a standalone
            // 256^3 GEMM (rows = matrix rows), not the MLP forward —
            // don't compare their rows/s against scalar_ref/gemm
            forward_rows.push(ForwardBenchRow::from_mean_s(
                "raw_gemm_sharded", m, shards, st.mean_ms / 1e3));
        }
        // sharded output stays bit-identical to the serial kernel
        let mut serial = vec![0.0f32; m * n];
        gemm_bias_act(m, n, k, &a, &w, Some(&bias), Epilogue::Silu, None,
                      &mut serial);
        gemm_sharded(m, n, k, &a, &w, Some(&bias), Epilogue::Silu, None,
                     &mut c, 8);
        assert_eq!(serial.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   c.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   "gemm_sharded changed bits");
        println!();
    }

    // --- ASD: verify rounds sharded across the pool -------------------
    let k = 150;
    let theta = 16;
    let gmm = Gmm::random(96, 128, 1.5, 7);
    let model: Arc<dyn DenoiseModel> = GmmDdpmOracle::new(gmm, k, false);
    let pool_sizes = [1usize, 2, 4, 8];
    let rows = sweep_pool_sizes(model.clone(), &pool_sizes, 2, theta, 4,
                                100)?;
    println!("[ASD theta={theta}, GMM d=96 x 128 components, K={k}]");
    print!("{}", format_pool_rows(k, &rows));
    assert!(outputs_bit_identical(&rows),
            "sharding changed sample bits: {rows:?}");
    println!("outputs bit-identical across pool sizes: true\n");

    // --- machine-readable artifact ------------------------------------
    let doc = bench_parallel_json(&forward_rows, k, theta, &rows);
    let path = std::path::Path::new("BENCH_parallel.json");
    write_bench_json(path, &doc)?;
    println!("wrote {} ({} forward rows, {} sweep rows)",
             path.display(), forward_rows.len(), rows.len());

    // --- coordinator: fused serving on the toy MLP variant ------------
    // closed-loop mixed traffic (sequential / ASD / Picard) at rising
    // concurrency; the fused-round row count is the batch the GEMM
    // forward actually sees. Emits BENCH_coordinator.json.
    println!("\n[coordinator: fused serving, toy MLP d={d} \
              hidden={hidden}]");
    {
        let coord_model: Arc<dyn DenoiseModel> = mlp.clone();
        let rows = bench_coordinator(
            coord_model, "toy-bench", &[1, 8, 64], 64,
            &ServerConfig { workers: 2, ..Default::default() }, 8)?;
        print!("{}", format_coord_rows(&rows));
        let doc = bench_coordinator_json("toy-bench", k_steps, &rows, None);
        let coord_path = std::path::Path::new("BENCH_coordinator.json");
        write_bench_json(coord_path, &doc)?;
        println!("wrote {}", coord_path.display());
        // the 64-way burst must actually fuse rows across requests
        let fused = rows.last().unwrap().fused_rows_per_round;
        assert!(fused > 1.0,
                "concurrency 64 served per-request (rows/round {fused:.2})");
    }

    // --- lockstep batched sequential: one sharded call per step -------
    println!("\n[lockstep batched sequential, n=32 chains, same model]");
    let seeds: Vec<u64> = (0..32).collect();
    let mut baseline_ms = 0.0;
    for &p in &pool_sizes {
        let sampler = BatchedSequentialSampler::with_pool(
            model.clone(), PoolConfig { pool_size: p, shard_min: 2 });
        let st = bench(1, 3, || {
            sampler.sample_batch(&seeds, &[]).unwrap();
        });
        if p == 1 {
            baseline_ms = st.mean_ms;
        }
        println!("{}  ({:.2}x vs pool=1)",
                 st.row(&format!("batched-seq n=32 pool={p}")),
                 baseline_ms / st.mean_ms.max(1e-12));
    }

    // acceptance floor, checked last so every section above ran and
    // the JSON artifact is already on disk whatever happens here
    assert!(speedup_b64 >= 4.0,
            "GEMM forward must be >= 4x the scalar ref at B=64, got \
             {speedup_b64:.2}x (see BENCH_parallel.json)");
    Ok(())
}
