//! Measured parallel-round execution: the native MLP's batched GEMM
//! forward vs its scalar reference, GEMM M-sharding on the worker
//! pool, and the ASD pool-size sweep (wall-clock next to algorithmic
//! rounds). Emits the machine-readable `BENCH_parallel.json` artifact
//! so the perf trajectory is tracked across PRs.
//!
//! Workloads:
//! * **native forward** — the default toy MLP variant (d=8, hidden=32,
//!   3 residual blocks, K=100 — the scale of the repo's real variants,
//!   where per-row libm exp/sin/cos and per-row scratch allocation
//!   dominate the row-at-a-time path); `denoise_batch` (GEMM pipeline
//!   + workspace + temb cache + vectorized SiLU) must beat
//!   `denoise_batch_ref` by >= 4x rows/s at B >= 64.
//! * **GEMM shape grid** — ref / v1 / packed / packed+2D-sharded over
//!   square training-ish shapes and small-M serve shapes (m ∈ {4, 16,
//!   64}); emits `BENCH_gemm.json` with GFLOP/s per kernel generation.
//! * **ASD sweep** — a wide random GMM oracle; outputs are asserted
//!   bit-identical across pool sizes (the pool buys wall-clock only).
//! * **Pareto grid** — sequential / ASD / SL-ASD / draft-SD over the
//!   analytic target × draft cells; emits `BENCH_pareto.json` (the
//!   speedup-vs-cost frontier tracked across PRs).
//!
//! Hard perf floors (the `>= 4x` GEMM-vs-scalar assert, the fused-rows
//! assert, the small-M packed-2D gain) read their thresholds from
//! `ASD_BENCH_MIN_SPEEDUP` / `ASD_BENCH_MIN_FUSED_ROWS` /
//! `ASD_BENCH_MIN_GEMM_GAIN` with the historical values as defaults,
//! so shared CI runners can relax them without editing the bench.
//!
//! Run: cargo bench --bench bench_parallel

use std::sync::Arc;

use asd::coordinator::ServerConfig;
use asd::ddpm::BatchedSequentialSampler;
use asd::exp::serve_bench::{bench_coordinator, bench_coordinator_json,
                            format_coord_rows};
use asd::exp::speedup::{bench_parallel_json, format_pool_rows,
                        gemm_serve_shapes, outputs_bit_identical,
                        run_gemm_grid, sweep_pool_sizes, write_bench_json,
                        ForwardBenchRow, GemmBenchRow};
use asd::math::gemm::{gemm_bias_act, gemm_sharded, Epilogue};
use asd::model::{DenoiseModel, Gmm, GmmDdpmOracle, NativeMlp, VariantInfo,
                 Workspace};
use asd::runtime::pool::{default_threads, PoolConfig};
use asd::util::timer::bench;

/// The default toy variant: a realistically-shaped small denoiser.
fn toy_mlp(d: usize, hidden: usize, blocks: usize, k_steps: usize)
           -> Arc<NativeMlp> {
    let info = VariantInfo::toy("toy-bench", d, 0, hidden, blocks, k_steps);
    let flat: Vec<f32> = (0..info.weights_len())
        .map(|i| {
            let h = (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
            ((h % 2003) as f32 / 2003.0 - 0.5) * 0.2
        })
        .collect();
    NativeMlp::from_flat(&info, &flat).expect("toy variant")
}

/// Acceptance-floor override for shared/noisy CI runners: thresholds
/// come from the environment with the historical values as defaults,
/// so a loaded runner can relax them without editing the bench.
fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    println!("=== Native GEMM forward + sharded worker pool \
              ({} pool threads available) ===\n", default_threads());

    // --- native MLP: GEMM pipeline vs scalar reference ----------------
    let d = 8usize;
    let (hidden, blocks, k_steps) = (32usize, 3usize, 100usize);
    let mlp = toy_mlp(d, hidden, blocks, k_steps);
    println!("[native MLP d={d} hidden={hidden} blocks={blocks}: \
              GEMM batch forward vs scalar ref]");
    let mut forward_rows: Vec<ForwardBenchRow> = Vec::new();
    let mut speedup_b64 = 0.0f64;
    for &b in &[1usize, 16, 64, 256] {
        let ys: Vec<f64> =
            (0..b * d).map(|i| (i as f64 * 0.37).sin()).collect();
        let ts: Vec<f64> = (0..b).map(|r| (1 + r % k_steps) as f64).collect();
        let mut out = vec![0.0; b * d];
        let mut ws = Workspace::new();
        let st_ref = bench(3, 20, || {
            mlp.denoise_batch_ref(&ys, &ts, &[], b, &mut out).unwrap();
        });
        let st_gemm = bench(3, 20, || {
            mlp.denoise_batch_with(&ys, &ts, &[], b, &mut out, &mut ws)
                .unwrap();
        });
        let r_ref = ForwardBenchRow::from_mean_s(
            "scalar_ref", b, 1, st_ref.mean_ms / 1e3);
        let r_gemm = ForwardBenchRow::from_mean_s(
            "gemm", b, 1, st_gemm.mean_ms / 1e3);
        let x = r_gemm.rows_per_s / r_ref.rows_per_s.max(1e-12);
        println!("B={b:<5} scalar_ref {:>12.0} rows/s ({:>8.0} ns/row)   \
                  gemm {:>12.0} rows/s ({:>8.0} ns/row)   {x:.2}x",
                 r_ref.rows_per_s, r_ref.ns_per_row,
                 r_gemm.rows_per_s, r_gemm.ns_per_row);
        if b == 64 {
            speedup_b64 = x;
        }
        forward_rows.push(r_ref);
        forward_rows.push(r_gemm);
    }
    // (the >= 4x floor is asserted at the very end, after
    // BENCH_parallel.json is written — a regression must not destroy
    // the artifact needed to diagnose it)
    println!("GEMM speedup at B=64: {speedup_b64:.2}x (floor: 4x)\n");

    // --- raw GEMM: M-sharding on the global pool ----------------------
    println!("[raw GEMM 256x256, B=256: M-sharded on the pool]");
    {
        let (m, n, k) = (256usize, 256usize, 256usize);
        let a: Vec<f32> =
            (0..m * k).map(|i| ((i % 601) as f32 / 601.0) - 0.5).collect();
        let w: Vec<f32> =
            (0..k * n).map(|i| ((i % 709) as f32 / 709.0) - 0.5).collect();
        let bias = vec![0.01f32; n];
        let mut c = vec![0.0f32; m * n];
        let mut base_ms = 0.0;
        for &shards in &[1usize, 2, 4, 8] {
            let st = bench(2, 10, || {
                gemm_sharded(m, n, k, &a, &w, Some(&bias), Epilogue::Silu,
                             None, &mut c, shards);
            });
            if shards == 1 {
                base_ms = st.mean_ms;
            }
            println!("{}  ({:.2}x vs serial)",
                     st.row(&format!("gemm_sharded shards={shards}")),
                     base_ms / st.mean_ms.max(1e-12));
            // distinct backend label: these rows measure a standalone
            // 256^3 GEMM (rows = matrix rows), not the MLP forward —
            // don't compare their rows/s against scalar_ref/gemm
            forward_rows.push(ForwardBenchRow::from_mean_s(
                "raw_gemm_sharded", m, shards, st.mean_ms / 1e3));
        }
        // sharded output stays bit-identical to the serial kernel
        let mut serial = vec![0.0f32; m * n];
        gemm_bias_act(m, n, k, &a, &w, Some(&bias), Epilogue::Silu, None,
                      &mut serial);
        gemm_sharded(m, n, k, &a, &w, Some(&bias), Epilogue::Silu, None,
                     &mut c, 8);
        assert_eq!(serial.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   c.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   "gemm_sharded changed bits");
        println!();
    }

    // --- GEMM shape grid: ref / v1 / packed / packed2d / chains -------
    // square training-ish shapes AND the small-M serve shapes where the
    // 2-D (M×N) split is what keeps the pool busy, plus the 3-GEMM
    // chain cells (barrier chain2d vs tile-graph pipelined). Emits
    // BENCH_gemm.json (every kernel's output is bit-checked against
    // gemm_ref inside the grid runner before its timing counts).
    let tile_shards = default_threads().clamp(1, 8);
    println!("[GEMM shape grid, tile_shards={tile_shards}]");
    let gemm_rows = run_gemm_grid(tile_shards, 2, 8,
                                  std::path::Path::new("BENCH_gemm.json"))?;
    println!();
    // worst small-M (m <= 16) packed2d-vs-v1 ratio, asserted at the end
    let gflops = |rows: &[GemmBenchRow], m: usize, kernel: &str| -> f64 {
        rows.iter()
            .find(|r| r.m == m && r.kernel == kernel)
            .map(|r| r.gflops)
            .unwrap_or(0.0)
    };
    let small_m_gain = gemm_serve_shapes()
        .iter()
        .filter(|(m, _, _)| *m <= 16)
        .map(|&(m, _, _)| {
            gflops(&gemm_rows, m, "packed2d")
                / gflops(&gemm_rows, m, "v1").max(1e-12)
        })
        .fold(f64::INFINITY, f64::min);
    println!("worst small-M packed2d/v1 gain: {small_m_gain:.2}x\n");
    // worst small-M pipelined-vs-chain2d ratio: the tile graph runs
    // the identical 3-layer chain without the two layer-boundary
    // barriers, so it must not lose to the barrier schedule
    let chain_gain = gemm_serve_shapes()
        .iter()
        .filter(|(m, _, _)| *m <= 16)
        .map(|&(m, _, _)| {
            gflops(&gemm_rows, m, "pipelined")
                / gflops(&gemm_rows, m, "chain2d").max(1e-12)
        })
        .fold(f64::INFINITY, f64::min);
    println!("worst small-M pipelined/chain2d gain: {chain_gain:.2}x\n");

    // --- ASD: verify rounds sharded across the pool -------------------
    let k = 150;
    let theta = 16;
    let gmm = Gmm::random(96, 128, 1.5, 7);
    let model: Arc<dyn DenoiseModel> = GmmDdpmOracle::new(gmm, k, false);
    let pool_sizes = [1usize, 2, 4, 8];
    let rows = sweep_pool_sizes(model.clone(), &pool_sizes, 2, theta, 4,
                                100)?;
    println!("[ASD theta={theta}, GMM d=96 x 128 components, K={k}]");
    print!("{}", format_pool_rows(k, &rows));
    assert!(outputs_bit_identical(&rows),
            "sharding changed sample bits: {rows:?}");
    println!("outputs bit-identical across pool sizes: true\n");

    // --- machine-readable artifact ------------------------------------
    let doc = bench_parallel_json(&forward_rows, k, theta, &rows);
    let path = std::path::Path::new("BENCH_parallel.json");
    write_bench_json(path, &doc)?;
    println!("wrote {} ({} forward rows, {} sweep rows)",
             path.display(), forward_rows.len(), rows.len());

    // --- coordinator: fused serving on the toy MLP variant ------------
    // closed-loop mixed traffic (sequential / ASD / Picard) at rising
    // concurrency; the fused-round row count is the batch the GEMM
    // forward actually sees. Emits BENCH_coordinator.json.
    println!("\n[coordinator: fused serving, toy MLP d={d} \
              hidden={hidden}]");
    {
        let coord_model: Arc<dyn DenoiseModel> = mlp.clone();
        let rows = bench_coordinator(
            coord_model, "toy-bench", &[1, 8, 64], 64,
            &ServerConfig { workers: 2, ..Default::default() }, 8)?;
        print!("{}", format_coord_rows(&rows));
        let doc = bench_coordinator_json("toy-bench", k_steps, &rows, None);
        let coord_path = std::path::Path::new("BENCH_coordinator.json");
        write_bench_json(coord_path, &doc)?;
        println!("wrote {}", coord_path.display());
        // the 64-way burst must actually fuse rows across requests
        // (floor overridable for shared runners — see env_f64)
        let fused = rows.last().unwrap().fused_rows_per_round;
        let min_fused = env_f64("ASD_BENCH_MIN_FUSED_ROWS", 1.0);
        assert!(fused > min_fused,
                "concurrency 64 served per-request (rows/round {fused:.2}, \
                 floor {min_fused:.2})");
    }

    // --- Pareto grid: sequential vs ASD vs SL-ASD vs draft-SD ---------
    // analytic cells only (the native MLP cells run under `asd pareto`
    // without --analytic); small n keeps the bench wall-clock sane.
    // Emits BENCH_pareto.json, schema v1.
    println!("\n[speedup-vs-cost Pareto grid, analytic cells]");
    asd::exp::speedup::run_pareto_grid(
        true, 2, 6, std::path::Path::new("BENCH_pareto.json"))?;

    // --- lockstep batched sequential: one sharded call per step -------
    println!("\n[lockstep batched sequential, n=32 chains, same model]");
    let seeds: Vec<u64> = (0..32).collect();
    let mut baseline_ms = 0.0;
    for &p in &pool_sizes {
        let sampler = BatchedSequentialSampler::with_pool(
            model.clone(), PoolConfig { pool_size: p, shard_min: 2 });
        let st = bench(1, 3, || {
            sampler.sample_batch(&seeds, &[]).unwrap();
        });
        if p == 1 {
            baseline_ms = st.mean_ms;
        }
        println!("{}  ({:.2}x vs pool=1)",
                 st.row(&format!("batched-seq n=32 pool={p}")),
                 baseline_ms / st.mean_ms.max(1e-12));
    }

    // acceptance floors, checked last so every section above ran and
    // the JSON artifacts are already on disk whatever happens here.
    // Thresholds read from the environment (defaults keep the
    // historical values) so shared CI runners can relax them.
    let min_speedup = env_f64("ASD_BENCH_MIN_SPEEDUP", 4.0);
    assert!(speedup_b64 >= min_speedup,
            "GEMM forward must be >= {min_speedup:.2}x the scalar ref at \
             B=64, got {speedup_b64:.2}x (see BENCH_parallel.json)");
    // packed+2D must beat the v1 kernel at small-M serve shapes once
    // the pool is real (>= 4 workers); floor 1.0 = parity, overridable
    if tile_shards >= 4 {
        let min_gain = env_f64("ASD_BENCH_MIN_GEMM_GAIN", 1.0);
        assert!(small_m_gain >= min_gain,
                "packed+2D GEMM must reach {min_gain:.2}x the v1 kernel \
                 at small-M serve shapes with {tile_shards} tile shards, \
                 got {small_m_gain:.2}x (see BENCH_gemm.json)");
        let min_chain = env_f64("ASD_BENCH_MIN_CHAIN_GAIN", 1.0);
        assert!(chain_gain >= min_chain,
                "pipelined tile graph must reach {min_chain:.2}x the \
                 chain2d barrier schedule at small-M serve shapes with \
                 {tile_shards} tile shards, got {chain_gain:.2}x (see \
                 BENCH_gemm.json)");
    }
    Ok(())
}
