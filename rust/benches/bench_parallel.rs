//! Measured parallel-round execution: wall-clock speedup from the
//! sharded worker pool, reported next to the algorithmic rounds
//! speedup — the bench that turns `parallel_rounds` from bookkeeping
//! into a measured quantity.
//!
//! Workload: a wide random GMM oracle (posterior-mean cost scales with
//! components * d), so per-row denoise work is large enough for
//! sharding to pay off. Outputs are asserted bit-identical across pool
//! sizes: the pool buys wall-clock only, never perturbs samples.
//!
//! Run: cargo bench --bench bench_parallel

use std::sync::Arc;

use asd::ddpm::BatchedSequentialSampler;
use asd::exp::speedup::{format_pool_rows, outputs_bit_identical,
                        sweep_pool_sizes};
use asd::model::{DenoiseModel, Gmm, GmmDdpmOracle};
use asd::runtime::pool::{default_threads, PoolConfig};
use asd::util::timer::bench;

fn main() -> anyhow::Result<()> {
    println!("=== Sharded worker pool — measured vs algorithmic speedup \
              ({} pool threads available) ===\n", default_threads());

    // --- ASD: verify rounds sharded across the pool -------------------
    let k = 150;
    let gmm = Gmm::random(96, 128, 1.5, 7);
    let model: Arc<dyn DenoiseModel> = GmmDdpmOracle::new(gmm, k, false);
    let pool_sizes = [1usize, 2, 4, 8];
    let rows = sweep_pool_sizes(model.clone(), &pool_sizes, 2, 16, 4, 100)?;
    println!("[ASD theta=16, GMM d=96 x 128 components, K={k}]");
    print!("{}", format_pool_rows(k, &rows));
    assert!(outputs_bit_identical(&rows),
            "sharding changed sample bits: {rows:?}");
    println!("outputs bit-identical across pool sizes: true\n");

    // --- lockstep batched sequential: one sharded call per step -------
    println!("[lockstep batched sequential, n=32 chains, same model]");
    let seeds: Vec<u64> = (0..32).collect();
    let mut baseline_ms = 0.0;
    for &p in &pool_sizes {
        let sampler = BatchedSequentialSampler::with_pool(
            model.clone(), PoolConfig { pool_size: p, shard_min: 2 });
        let st = bench(1, 3, || {
            sampler.sample_batch(&seeds, &[]).unwrap();
        });
        if p == 1 {
            baseline_ms = st.mean_ms;
        }
        println!("{}  ({:.2}x vs pool=1)",
                 st.row(&format!("batched-seq n=32 pool={p}")),
                 baseline_ms / st.mean_ms.max(1e-12));
    }
    Ok(())
}
