//! End-to-end ASD parity: replay the golden (u, xi) streams through the
//! rust engine over the HLO gmm2d model and reproduce the python
//! reference implementation's outputs, stats and the sequential sample.

mod common;

use asd::asd::{AsdConfig, AsdEngine, KernelBackend};
use asd::ddpm::{NoiseStreams, SequentialSampler};
use common::{approx_eq_slice, golden};

fn golden_noise() -> (NoiseStreams, &'static asd::util::Json) {
    let g = golden().get("asd_gmm2d").unwrap();
    let y_k = g.get("y_k").unwrap().as_f64_vec().unwrap();
    let xi: Vec<f64> = g.get("xi").unwrap().as_arr().unwrap()
        .iter().flat_map(|r| r.as_f64_vec().unwrap()).collect();
    let u = g.get("u").unwrap().as_f64_vec().unwrap();
    (NoiseStreams { y_k, xi, u }, g)
}

#[test]
fn sequential_matches_python_reference() {
    let Some(rt) = common::try_runtime() else { return };
    if common::try_golden().is_none() {
        return;
    }
    let model = rt.model("gmm2d").unwrap();
    let (noise, g) = golden_noise();
    let sampler = SequentialSampler::new(model);
    let (y0, stats) = sampler.sample_with_noise(&noise, &[]).unwrap();
    assert_eq!(stats.model_calls, 100);
    let want = g.get("sequential_y0").unwrap().as_f64_vec().unwrap();
    approx_eq_slice(&y0, &want, 5e-3, "sequential y0");
}

#[test]
fn asd_traces_match_python_reference() {
    let Some(rt) = common::try_runtime() else { return };
    if common::try_golden().is_none() {
        return;
    }
    let model = rt.model("gmm2d").unwrap();
    let (noise, g) = golden_noise();
    for theta_key in ["4", "8", "0"] {
        let tr = g.get("asd").unwrap().get(theta_key).unwrap();
        let theta: usize = theta_key.parse().unwrap();
        let mut engine = AsdEngine::new(
            model.clone(),
            AsdConfig {
                theta,
                eval_tail: true,
                backend: KernelBackend::Native,
                ..Default::default()
            },
        );
        let out = engine.sample_with_noise(&noise, &[]).unwrap();
        let want_y0 = tr.get("y0").unwrap().as_f64_vec().unwrap();
        approx_eq_slice(&out.y0, &want_y0, 5e-3,
                        &format!("asd theta={theta_key} y0"));
        for (field, got) in [
            ("model_calls", out.stats.model_calls),
            ("parallel_rounds", out.stats.parallel_rounds),
            ("iterations", out.stats.iterations),
            ("accepted", out.stats.accepted),
            ("rejected", out.stats.rejected),
        ] {
            let want = tr.get(field).unwrap().as_usize().unwrap();
            assert_eq!(got, want,
                       "asd theta={theta_key} {field}: rust {got} vs py {want}");
        }
    }
}

#[test]
fn asd_hlo_kernel_backend_matches_native_backend() {
    let Some(rt) = common::try_runtime() else { return };
    if common::try_golden().is_none() {
        return;
    }
    let model = rt.model("gmm2d").unwrap();
    let (noise, _) = golden_noise();
    let mut native = AsdEngine::new(
        model.clone(),
        AsdConfig {
            theta: 8,
            eval_tail: true,
            backend: KernelBackend::Native,
            ..Default::default()
        },
    );
    let mut hlo = AsdEngine::new(
        model.clone(),
        AsdConfig {
            theta: 8,
            eval_tail: true,
            backend: KernelBackend::Hlo(rt.kernels(model.info.d).unwrap()),
            ..Default::default()
        },
    );
    let out_n = native.sample_with_noise(&noise, &[]).unwrap();
    let out_h = hlo.sample_with_noise(&noise, &[]).unwrap();
    // identical accept/reject paths expected (f32 kernel vs f64 native can
    // only diverge on knife-edge decisions; this trace has none)
    assert_eq!(out_n.stats.accepted, out_h.stats.accepted);
    assert_eq!(out_n.stats.rejected, out_h.stats.rejected);
    approx_eq_slice(&out_n.y0, &out_h.y0, 1e-3, "hlo vs native backend y0");
}
