//! Fault injection must be as deterministic as the sampling it breaks.
//!
//! A [`FaultPlan`] is a pure function of `(lane, round, site)`, so the
//! same seeded chaos run must produce the *identical* outcome vector —
//! which requests fail, with what reason, after how many retries, and
//! the exact bits of every survivor — at every worker-pool size. The
//! survivors must additionally be bit-identical to a fault-free run:
//! retry-from-scratch rebuilds a machine that consumes only its own
//! pre-drawn Philox streams, so recovery is bit-transparent.
//!
//! The third leg pushes a mid-graph tile fault through the coordinator:
//! with injection restricted to one NativeMlp lane, only that lane's
//! rounds may fail (reason `TilePanic` — the panic happened on a pool
//! worker inside a compiled tile graph, and the cancel-dependents path
//! contained it), while the sibling lane's burst stays bit-identical
//! to solo execution.

use std::sync::Arc;
use std::time::Duration;

use asd::asd::{AsdConfig, AsdEngine};
use asd::coordinator::{Coordinator, FailReason, RecoveryPolicy, Request,
                       SamplerSpec, ServerConfig};
use asd::ddpm::SequentialSampler;
use asd::faults::{run_chaos_burst, ChaosOutcome, FaultPlan};
use asd::model::{DenoiseModel, Gmm, GmmDdpmOracle, NativeMlp, VariantInfo};
use asd::runtime::pool::PoolConfig;

const POOL_SIZES: [usize; 3] = [1, 2, 8];
const K: usize = 20;
const LANE: &str = "gmm";

fn model() -> Arc<dyn DenoiseModel> {
    GmmDdpmOracle::new(Gmm::random(8, 6, 1.5, 3), K, false)
}

/// Imperfect draft for [`model`] (means shifted 0.05, alternating
/// sign), same shape the fusion determinism suite uses.
fn draft_model() -> Arc<dyn DenoiseModel> {
    let base = Gmm::random(8, 6, 1.5, 3);
    let means: Vec<Vec<f64>> = (0..base.weights.len())
        .map(|c| {
            base.mean_of(c).iter().enumerate()
                .map(|(i, &v)| {
                    v + 0.05 * if i % 2 == 0 { 1.0 } else { -1.0 }
                })
                .collect()
        })
        .collect();
    let gmm = Gmm::new(means, base.sigmas.clone(), base.weights.clone());
    GmmDdpmOracle::new(gmm, K, false)
}

fn bits(v: &[f64]) -> Vec<u64> {
    asd::math::vec_ops::to_bits_vec(v)
}

/// Mixed burst: all four sampler kinds, three of each.
fn burst_specs() -> Vec<(SamplerSpec, u64)> {
    (0..12u64)
        .map(|i| {
            let spec = match i % 4 {
                0 => SamplerSpec::Sequential,
                1 => SamplerSpec::Asd(8),
                2 => SamplerSpec::Picard(8, 1e-6),
                _ => SamplerSpec::Draft(8),
            };
            (spec, 1000 + i)
        })
        .collect()
}

/// A panic-only plan whose first injected fault provably lands inside
/// the burst's round horizon (every burst runs at least K rounds — it
/// contains sequential machines), found by scanning seeds with the
/// plan's own pure query instead of hoping.
fn plan_with_early_fault(rate: f64) -> FaultPlan {
    (0..64u64)
        .map(|s| FaultPlan::panics(s, rate))
        .find(|p| p.first_fault(LANE, K as u64).is_some())
        .expect("no seed in 0..64 faults within the horizon")
}

fn recovery(retry_max: u32) -> RecoveryPolicy {
    RecoveryPolicy {
        retry_max,
        backoff_rounds: 1,
        // high enough that the breaker never interferes with the
        // completeness/determinism claims under ambient chaos
        breaker_threshold: 100,
        breaker_cooldown: Duration::from_millis(50),
        validate_outputs: true,
    }
}

fn run(plan: Option<&FaultPlan>, retry_max: u32, pool_size: usize)
       -> Vec<ChaosOutcome> {
    run_chaos_burst(model(), Some(draft_model()), LANE, plan,
                    recovery(retry_max),
                    PoolConfig { pool_size, shard_min: 1 },
                    &burst_specs())
}

/// Assert two chaos runs are outcome-identical: same failure set, same
/// reasons and messages, same retry counts, same survivor bits.
fn assert_outcomes_identical(a: &[ChaosOutcome], b: &[ChaosOutcome],
                             ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: outcome count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{ctx}: id order");
        assert_eq!(x.error, y.error, "{ctx}: request {} error", x.id);
        assert_eq!(x.reason, y.reason, "{ctx}: request {} reason", x.id);
        assert_eq!(x.retries, y.retries, "{ctx}: request {} retries",
                   x.id);
        assert_eq!(bits(&x.sample), bits(&y.sample),
                   "{ctx}: request {} sample bits", x.id);
    }
}

#[test]
fn same_seed_same_failures_and_survivor_bits_across_pool_sizes() {
    // no-retry leg: the faulted rounds' participants fail, and the
    // whole outcome vector is a pure function of the plan seed
    let plan = plan_with_early_fault(0.2);
    let clean = run(None, 0, 1);
    assert!(clean.iter().all(|o| o.error.is_none()),
            "fault-free burst must complete fully");

    let reference = run(Some(&plan), 0, POOL_SIZES[0]);
    let failures: Vec<u64> = reference.iter()
        .filter(|o| o.error.is_some()).map(|o| o.id).collect();
    assert!(!failures.is_empty(),
            "plan seed {} injected no failure", plan.seed);
    for o in &reference {
        match &o.error {
            Some(msg) => {
                assert_eq!(o.reason, Some(FailReason::ModelPanic),
                           "request {}: {msg}", o.id);
                assert!(msg.contains("panicked"), "request {}: {msg}",
                        o.id);
            }
            // survivors are bit-identical to the fault-free run:
            // requests that never shared a faulted round are untouched
            None => assert_eq!(bits(&o.sample),
                               bits(&clean[o.id as usize].sample),
                               "survivor {} drifted from fault-free bits",
                               o.id),
        }
    }
    for &pool_size in &POOL_SIZES[1..] {
        let got = run(Some(&plan), 0, pool_size);
        assert_outcomes_identical(&reference, &got,
                                  &format!("pool_size={pool_size}"));
    }
}

#[test]
fn retries_recover_bit_transparently_across_pool_sizes() {
    // retry leg: the same plan with generous retries must *retry*
    // (the fault still fires) and every recovered request's bits must
    // equal the fault-free run — retry-from-scratch re-consumes the
    // same pre-drawn noise streams
    let plan = plan_with_early_fault(0.2);
    let clean = run(None, 0, 1);
    let reference = run(Some(&plan), 10, POOL_SIZES[0]);
    let total_retries: u32 = reference.iter().map(|o| o.retries).sum();
    assert!(total_retries > 0, "plan seed {} never triggered a retry",
            plan.seed);
    for o in &reference {
        if o.error.is_none() {
            assert_eq!(bits(&o.sample), bits(&clean[o.id as usize].sample),
                       "request {} ({} retries) not bit-transparent",
                       o.id, o.retries);
        }
    }
    for &pool_size in &POOL_SIZES[1..] {
        let got = run(Some(&plan), 10, pool_size);
        assert_outcomes_identical(&reference, &got,
                                  &format!("pool_size={pool_size}"));
    }
}

/// Toy in-memory NativeMlp variant (same layout the fusion determinism
/// suite uses) with `seed_mul` varying the pseudo-random weights.
fn toy_mlp(name: &str, seed_mul: usize) -> Arc<dyn DenoiseModel> {
    let info = VariantInfo::toy(name, 3, 0, 16, 1, 40);
    let n_w = info.weights_len();
    let flat: Vec<f32> = (0..n_w)
        .map(|i| ((i * seed_mul % 101) as f32 / 101.0) - 0.5)
        .collect();
    NativeMlp::from_flat(&info, &flat).unwrap()
}

#[test]
fn tile_faults_stay_inside_their_lane() {
    // mid-graph leg: injection restricted to lane "a" (tile_rate 1 —
    // every compiled round of lane a gets one poisoned node). Lane a's
    // failures must carry the TilePanic reason (the panic happened on
    // a pool worker mid-graph and rode the cancel-dependents path);
    // lane b — same chaos'd coordinator, same pool — must complete
    // fully and bit-identical to solo execution.
    let a = toy_mlp("a", 37);
    let b = toy_mlp("b", 53);
    let specs: Vec<(SamplerSpec, u64)> = (0..8u64)
        .map(|i| {
            let spec = if i % 2 == 0 {
                SamplerSpec::Sequential
            } else {
                SamplerSpec::Asd(8)
            };
            (spec, 7000 + i)
        })
        .collect();
    let solo = |m: &Arc<dyn DenoiseModel>, spec: SamplerSpec, seed: u64| {
        match spec {
            SamplerSpec::Sequential => {
                SequentialSampler::new(m.clone()).sample(seed, &[])
                    .unwrap().0
            }
            SamplerSpec::Asd(theta) => {
                AsdEngine::new(m.clone(),
                               AsdConfig { theta, ..Default::default() })
                    .sample(seed).unwrap().y0
            }
            _ => unreachable!(),
        }
    };
    let c = Coordinator::new(ServerConfig {
        workers: 2,
        max_batch: 16,
        enable_batching: true,
        pool: PoolConfig { pool_size: 2, shard_min: 1 },
        recovery: recovery(0),
        faults: Some(FaultPlan {
            seed: 11,
            tile_rate: 1.0,
            only_lane: Some("a".into()),
            ..FaultPlan::default()
        }),
        ..Default::default()
    }).unwrap();
    c.register_model("a", a.clone());
    c.register_model("b", b.clone());
    let mut rxs = Vec::new();
    for &(spec, seed) in &specs {
        for variant in ["a", "b"] {
            rxs.push((variant, spec, seed, c.submit(Request {
                id: 0,
                variant: variant.into(),
                sampler: spec,
                seed,
                cond: vec![],
                deadline: None,
            }).1));
        }
    }
    let mut a_tile_failures = 0u32;
    for (variant, spec, seed, rx) in rxs {
        let r = rx.recv().unwrap();
        match variant {
            "a" => match &r.error {
                Some(msg) => {
                    // the only way a lane-a round fails is the poisoned
                    // tile: mid-graph containment, not a whole-model
                    // panic at round granularity
                    assert_eq!(r.reason, Some(FailReason::TilePanic),
                               "lane a seed {seed}: {msg}");
                    assert!(msg.contains("tile"),
                            "lane a seed {seed}: {msg}");
                    a_tile_failures += 1;
                }
                // a round too small to compile a graph gives the tile
                // fault nothing to land on and must execute clean —
                // still bit-exact
                None => assert_eq!(bits(&r.sample),
                                   bits(&solo(&a, spec, seed)),
                                   "clean lane-a request {seed} drifted"),
            },
            _ => {
                assert!(r.error.is_none(),
                        "lane b seed {seed} collateral failure: {:?}",
                        r.error);
                assert_eq!(bits(&r.sample), bits(&solo(&b, spec, seed)),
                           "lane b seed {seed} drifted under sibling \
                            chaos");
            }
        }
    }
    assert!(a_tile_failures > 0,
            "tile_rate 1.0 never landed a mid-graph fault on lane a");
    let m = c.metrics();
    let lane_b = m.lanes.iter().find(|l| l.lane == "b").unwrap();
    assert_eq!(lane_b.admitted, 8);
    for (name, v) in [("rejected", lane_b.rejected),
                      ("timed_out", lane_b.timed_out),
                      ("retried", lane_b.retried),
                      ("breaker_trips", lane_b.breaker_trips)] {
        assert_eq!(v, 0, "lane b {name} moved under lane-a chaos");
    }
    c.shutdown();
}
