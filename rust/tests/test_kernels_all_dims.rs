//! HLO L1 kernel parity across every lowered dimension, plus
//! property-style sweeps of the runtime padding/chunking invariants.

mod common;

use asd::asd::grs_native;
use asd::model::DenoiseModel;
use asd::rng::Philox;
use common::approx_eq_slice;

fn check_kernels_for_dim(d: usize) {
    let Some(rt) = common::try_runtime() else { return };
    let kernels = rt.kernels(d).unwrap();
    let mut rng = Philox::new(d as u64, 0);
    for t in [1usize, 3, 17, 32] {
        let y_a: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let x0a: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let c1: Vec<f64> = (0..t).map(|_| rng.uniform() * 0.2).collect();
        let c2: Vec<f64> = (0..t).map(|_| 0.8 + rng.uniform() * 0.2).collect();
        let sigma: Vec<f64> = (0..t).map(|_| rng.uniform() * 0.3).collect();
        let xi: Vec<f64> = (0..t * d).map(|_| rng.normal()).collect();

        let (m_hlo, y_hlo) = kernels
            .speculate(&y_a, &x0a, &c1, &c2, &sigma, &xi)
            .unwrap();
        // native recurrence
        let mut m_nat = vec![0.0; t * d];
        let mut y_nat = vec![0.0; t * d];
        let mut prev = y_a.clone();
        for k in 0..t {
            for i in 0..d {
                m_nat[k * d + i] = c1[k] * x0a[i] + c2[k] * prev[i];
                y_nat[k * d + i] = m_nat[k * d + i] + sigma[k] * xi[k * d + i];
            }
            prev = y_nat[k * d..(k + 1) * d].to_vec();
        }
        approx_eq_slice(&m_hlo, &m_nat, 2e-4, &format!("spec d={d} t={t}"));
        approx_eq_slice(&y_hlo, &y_nat, 2e-4, &format!("spec-y d={d} t={t}"));

        // verify kernel vs native GRS on the same data
        let u: Vec<f64> = (0..t).map(|_| rng.uniform()).collect();
        let m_tgt: Vec<f64> = m_nat.iter().map(|x| x + 0.05).collect();
        let sig1: Vec<f64> = (0..t).map(|_| 0.2 + rng.uniform()).collect();
        let (z_hlo, acc_hlo) = kernels
            .verify(&u, &xi, &m_nat, &m_tgt, &sig1)
            .unwrap();
        let mut z = vec![0.0; d];
        let mut v = vec![0.0; d];
        for k in 0..t {
            let ok = grs_native(u[k], &xi[k * d..(k + 1) * d],
                                &m_nat[k * d..(k + 1) * d],
                                &m_tgt[k * d..(k + 1) * d], sig1[k],
                                &mut z, &mut v);
            assert_eq!(ok, acc_hlo[k], "accept d={d} t={t} row {k}");
            approx_eq_slice(&z_hlo[k * d..(k + 1) * d], &z, 2e-3,
                            &format!("verify-z d={d} t={t} row {k}"));
        }
    }
}

#[test]
fn kernels_d16() {
    check_kernels_for_dim(16);
}

#[test]
fn kernels_d64() {
    check_kernels_for_dim(64);
}

#[test]
fn kernels_d112() {
    check_kernels_for_dim(112);
}

#[test]
fn kernels_d224() {
    check_kernels_for_dim(224);
}

#[test]
fn chain_longer_than_kernel_t_is_rejected() {
    let Some(rt) = common::try_runtime() else { return };
    let kernels = rt.kernels(16).unwrap();
    let too_long = kernels.t_steps + 1;
    let err = kernels.speculate(&vec![0.0; 16], &vec![0.0; 16],
                                &vec![0.1; too_long], &vec![0.9; too_long],
                                &vec![0.1; too_long],
                                &vec![0.0; too_long * 16]);
    assert!(err.is_err());
}

#[test]
fn padding_rows_do_not_leak_into_results() {
    // two different paddings of the same 3-row problem must agree
    let Some(rt) = common::try_runtime() else { return };
    let model = rt.model("latent16").unwrap();
    let d = model.dim();
    let c = model.cond_dim();
    let mut rng = Philox::new(3, 1);
    let ys: Vec<f64> = (0..3 * d).map(|_| rng.normal()).collect();
    let ts = vec![500.0, 2.0, 999.0];
    let cond = vec![0.1; 3 * c];
    let mut out_a = vec![0.0; 3 * d];
    model.denoise_batch(&ys, &ts, &cond, 3, &mut out_a).unwrap();
    // same rows through batch-1 calls
    for r in 0..3 {
        let mut one = vec![0.0; d];
        model.denoise_batch(&ys[r * d..(r + 1) * d], &ts[r..r + 1],
                            &cond[r * c..(r + 1) * c], 1, &mut one).unwrap();
        approx_eq_slice(&out_a[r * d..(r + 1) * d], &one, 1e-5,
                        &format!("padded row {r}"));
    }
}

#[test]
fn asd_with_hlo_policy_model_smoke() {
    // full-stack: ASD over an HLO policy model with obs conditioning
    use asd::asd::{AsdConfig, AsdEngine, KernelBackend};
    let Some(rt) = common::try_runtime() else { return };
    let model = rt.model("policy_square").unwrap();
    let c = model.cond_dim();
    let mut engine = AsdEngine::new(
        model.clone(),
        AsdConfig { theta: 16, eval_tail: true,
                    backend: KernelBackend::Native,
                    ..Default::default() });
    let obs = vec![0.2; c];
    let out = engine.sample_cond(5, &obs).unwrap();
    assert_eq!(out.y0.len(), 112);
    assert!(out.y0.iter().all(|v| v.is_finite()));
    assert!(out.stats.parallel_rounds < 100);
    assert_eq!(out.stats.accepted + out.stats.rejected, 100);
}
