//! Property-based sweeps over the pure substrates (no PJRT needed):
//! JSON roundtrips, quality-metric axioms, lane-queue invariants under
//! random queues, Picard-vs-sequential convergence, schedule identities
//! at random K, GEMM-vs-naive-reference parity (v1, prepacked-panel
//! and 2-D M×N-sharded kernels all bitwise vs `gemm_ref`; the native
//! MLP's packed GEMM batch path vs its scalar reference, incl. tiled
//! bit-invariance), quantized `PackedB` pack/dequant round-trips and
//! the int8/f16 denoise error-bound sweep, `exp_fast` edge semantics
//! + a max-ulp sweep vs libm, and worker-pool sharding invariants
//! (sharded == unsharded bitwise; GRS accept counts invariant under
//! pool size and kernel backend).

mod common;

use asd::math::stats::{ks_critical, ks_statistic};
use asd::quality::{frechet_diag, sliced_w};
use asd::rng::Philox;
use asd::schedule::DdpmSchedule;
use asd::util::prop;
use asd::util::Json;

#[test]
fn json_roundtrip_random_structures() {
    prop::check("json-roundtrip", 60, |g| {
        let v = random_json(g, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| {
            panic!("reparse failed for {text}: {e}");
        });
        assert_eq!(v, back, "roundtrip mismatch for {text}");
    });
}

fn random_json(g: &mut prop::Gen, depth: usize) -> Json {
    let choice = if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) };
    match choice {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => {
            // f64s that survive text roundtrips exactly
            Json::Num((g.f64_in(-1e6, 1e6) * 64.0).round() / 64.0)
        }
        3 => {
            let n = g.usize_in(0, 8);
            let s: String = (0..n)
                .map(|_| *g.pick(&['a', 'b', '"', '\\', 'x', '\n', '7']))
                .collect();
            Json::Str(s)
        }
        4 => {
            let n = g.usize_in(0, 4);
            Json::Arr((0..n).map(|_| random_json(g, depth - 1)).collect())
        }
        _ => {
            let n = g.usize_in(0, 4);
            Json::Obj((0..n)
                .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
                .collect())
        }
    }
}

#[test]
fn frechet_axioms() {
    prop::check("frechet-axioms", 25, |g| {
        let d = g.usize_in(1, 6);
        let n = 60;
        let a: Vec<Vec<f64>> = (0..n).map(|_| g.normal_vec(d)).collect();
        let b: Vec<Vec<f64>> = (0..n)
            .map(|_| g.normal_vec(d).iter().map(|x| x + 1.0).collect())
            .collect();
        // identity of indiscernibles (same cloud)
        assert!(frechet_diag(&a, &a) < 1e-12);
        // symmetry
        let ab = frechet_diag(&a, &b);
        let ba = frechet_diag(&b, &a);
        assert!((ab - ba).abs() < 1e-9);
        // non-negativity + detects the shift
        assert!(ab > 0.0);
        // sliced-W symmetric-ish (same projections both ways)
        let sab = sliced_w(&a, &b);
        assert!(sab > 0.0);
        assert!((sab - sliced_w(&b, &a)).abs() < 1e-9);
    });
}

#[test]
fn schedule_identities_at_random_k() {
    prop::check("schedule-identities", 20, |g| {
        let k = g.usize_in(20, 1500);
        let s = DdpmSchedule::new(k);
        for i in 0..k {
            let mean_id = s.c1[i] + s.c2[i] * s.abar[i].sqrt();
            assert!((mean_id - s.abar_prev[i].sqrt()).abs() < 1e-9,
                    "K={k} i={i}");
            let var_id = s.c2[i] * s.c2[i] * (1.0 - s.abar[i])
                + s.sigma[i] * s.sigma[i];
            assert!((var_id - (1.0 - s.abar_prev[i])).abs() < 1e-9);
        }
    });
}

#[test]
fn philox_streams_pass_ks_against_each_other() {
    // two disjoint streams should be indistinguishable in law
    let mut a = Philox::new(1, 10);
    let mut b = Philox::new(1, 11);
    let n = 20_000;
    let va: Vec<f64> = (0..n).map(|_| a.normal()).collect();
    let vb: Vec<f64> = (0..n).map(|_| b.normal()).collect();
    let d = ks_statistic(&va, &vb);
    assert!(d < ks_critical(n, n, 0.001), "KS {d}");
}

#[test]
fn picard_converges_for_random_gmm_targets() {
    use asd::ddpm::{NoiseStreams, SequentialSampler};
    use asd::model::{Gmm, GmmDdpmOracle};
    use asd::picard::{PicardConfig, PicardSampler};

    prop::check("picard-converges", 6, |g| {
        let n_comp = g.usize_in(2, 5);
        let d = 2;
        let means: Vec<Vec<f64>> = (0..n_comp)
            .map(|_| g.normal_vec(d).iter().map(|x| 1.5 * x).collect())
            .collect();
        let gmm = Gmm::new(means, vec![0.2; n_comp],
                           vec![1.0 / n_comp as f64; n_comp]);
        let k = 30;
        let oracle = GmmDdpmOracle::new(gmm, k, false);
        let seq = SequentialSampler::new(oracle.clone());
        let pic = PicardSampler::new(
            oracle, PicardConfig { window: 6, tol: 1e-10, max_sweeps: 400,
                                   ..Default::default() });
        let noise = NoiseStreams::draw(g.seed, 0, k, d);
        let (a, _) = seq.sample_with_noise(&noise, &[]).unwrap();
        let (b, _) = pic.sample_with_noise(&noise, &[]).unwrap();
        assert!(asd::math::vec_ops::dist(&a, &b) < 1e-4,
                "picard diverged: {a:?} vs {b:?}");
    });
}

#[test]
fn asd_engine_invariants_random_theta() {
    use asd::asd::{AsdConfig, AsdEngine, KernelBackend};
    use asd::model::{Gmm, GmmDdpmOracle};
    use asd::runtime::pool::PoolConfig;

    prop::check("asd-invariants", 12, |g| {
        let k = g.usize_in(10, 120);
        let theta = *g.pick(&[0usize, 1, 2, 5, 9, 33]);
        let pool_size = *g.pick(&[1usize, 2, 5]);
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), k, false);
        let mut e = AsdEngine::new(
            oracle,
            AsdConfig { theta, eval_tail: g.bool(),
                        backend: KernelBackend::Native,
                        pool: PoolConfig { pool_size, shard_min: 1 } });
        let out = e.sample(g.seed).unwrap();
        // every transition consumed exactly once
        assert_eq!(out.stats.accepted + out.stats.rejected, k);
        // Lemma 13: >= 1 accept per iteration
        assert!(out.stats.accepted >= out.stats.iterations);
        // round bookkeeping is consistent
        assert_eq!(out.stats.round_batches.len(), out.stats.parallel_rounds);
        assert_eq!(out.stats.round_batches.iter().sum::<usize>(),
                   out.stats.model_calls);
        assert_eq!(out.stats.round_shards.len(), out.stats.parallel_rounds);
        assert_eq!(out.stats.round_latency_s.len(),
                   out.stats.parallel_rounds);
        // occupancy never exceeds the configured pool size or the batch
        for (i, &s) in out.stats.round_shards.iter().enumerate() {
            assert!(s >= 1 && s <= pool_size.max(1));
            assert!(s <= out.stats.round_batches[i].max(1));
        }
        // sample is finite and 2-D
        assert_eq!(out.y0.len(), 2);
        assert!(out.y0.iter().all(|v| v.is_finite()));
    });
}

#[test]
fn gemm_matches_naive_reference_and_shards_bitwise() {
    use asd::math::gemm::{gemm_bias_act, gemm_packed_bias_act,
                          gemm_packed_sharded, gemm_ref, gemm_sharded,
                          Epilogue, PackedB};

    prop::check("gemm-vs-naive", 40, |g| {
        // odd/rectangular shapes straddling the register tile (MR=4),
        // the packed column panel (NR=8) and the k cache panel
        // (KC=256); B=0 and B=1 edge cases
        let m = *g.pick(&[0usize, 1, 2, 3, 4, 5, 7, 12, 33]);
        let n = g.usize_in(1, 24);
        let k = *g.pick(&[1usize, 2, 7, 31, 64, 300]);
        let to_f32 = |v: Vec<f64>| -> Vec<f32> {
            v.into_iter().map(|x| x as f32).collect()
        };
        let a = to_f32(g.normal_vec(m * k));
        let b = to_f32(g.normal_vec(k * n));
        let bias_v = to_f32(g.normal_vec(n));
        let res_v = to_f32(g.normal_vec(m * n));
        let bias = g.bool().then_some(&bias_v[..]);
        let res = g.bool().then_some(&res_v[..]);
        let epi = if g.bool() { Epilogue::Silu } else { Epilogue::Linear };

        let mut want = vec![0.0f32; m * n];
        gemm_ref(m, n, k, &a, &b, bias, epi, res, &mut want);
        let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();

        let mut got = vec![0.0f32; m * n];
        gemm_bias_act(m, n, k, &a, &b, bias, epi, res, &mut got);
        let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        assert_eq!(want_bits, got_bits,
                   "blocked kernel diverged: m={m} n={n} k={k} epi={epi:?}");

        // the prepacked-panel kernel is bit-identical to the naive
        // reference by construction
        let pb = PackedB::pack(k, n, &b);
        let mut packed = vec![0.0f32; m * n];
        gemm_packed_bias_act(m, n, k, &a, &pb, bias, epi, res, &mut packed);
        let packed_bits: Vec<u32> =
            packed.iter().map(|v| v.to_bits()).collect();
        assert_eq!(want_bits, packed_bits,
                   "packed kernel diverged: m={m} n={n} k={k} epi={epi:?}");

        // 2-D (M×N) sharded execution on the global pool is
        // bit-invariant in the shard count, for both kernel generations
        for shards in [2usize, 3, 8, 64] {
            let mut sh = vec![0.0f32; m * n];
            gemm_sharded(m, n, k, &a, &b, bias, epi, res, &mut sh, shards);
            let sh_bits: Vec<u32> = sh.iter().map(|v| v.to_bits()).collect();
            assert_eq!(want_bits, sh_bits,
                       "shards={shards} changed bits: m={m} n={n} k={k}");
            let mut psh = vec![0.0f32; m * n];
            gemm_packed_sharded(m, n, k, &a, &pb, bias, epi, res, &mut psh,
                                shards);
            let psh_bits: Vec<u32> =
                psh.iter().map(|v| v.to_bits()).collect();
            assert_eq!(want_bits, psh_bits,
                       "packed shards={shards} changed bits: m={m} n={n} \
                        k={k}");
        }
    });
}

#[test]
fn packed_gemm_2d_sharding_is_pool_invariant_at_serve_shapes() {
    use asd::math::gemm::{gemm_packed_sharded, gemm_ref, Epilogue,
                          PackedB};

    // the small-M serving shapes the 2-D scheduler exists for: a
    // single MR row block fans out over NR column panels; pool sizes
    // 1/2/8 must produce identical bits
    for &(m, n, k) in &[(4usize, 96usize, 64usize), (2, 64, 300),
                        (16, 40, 17)] {
        let a: Vec<f32> =
            (0..m * k).map(|i| ((i % 211) as f32 / 211.0) - 0.5).collect();
        let b: Vec<f32> =
            (0..k * n).map(|i| ((i % 223) as f32 / 223.0) - 0.5).collect();
        let bias: Vec<f32> =
            (0..n).map(|i| ((i % 19) as f32 / 19.0) - 0.5).collect();
        let pb = PackedB::pack(k, n, &b);
        let mut want = vec![0.0f32; m * n];
        gemm_ref(m, n, k, &a, &b, Some(&bias), Epilogue::Silu, None,
                 &mut want);
        let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
        for pool in [1usize, 2, 8] {
            let mut got = vec![0.0f32; m * n];
            let eff = gemm_packed_sharded(m, n, k, &a, &pb, Some(&bias),
                                          Epilogue::Silu, None, &mut got,
                                          pool);
            assert!(eff >= 1 && eff <= pool.max(1));
            let got_bits: Vec<u32> =
                got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(want_bits, got_bits,
                       "m={m} n={n} k={k} pool={pool}");
        }
    }
}

#[test]
fn exp_fast_edge_semantics_and_max_ulp_vs_libm() {
    use asd::math::gemm::exp_fast;

    // exactness at 0 (both signs)
    assert_eq!(exp_fast(0.0).to_bits(), 1.0f32.to_bits());
    assert_eq!(exp_fast(-0.0).to_bits(), 1.0f32.to_bits());
    // NaN propagation
    assert!(exp_fast(f32::NAN).is_nan());
    // +overflow saturation: inf at and past libm's 88.7228 overflow
    // point, and — by the documented early-saturation contract — from
    // the 88.3 clamp point on (no band that silently underestimates)
    assert_eq!(exp_fast(88.73), f32::INFINITY);
    assert_eq!(exp_fast(150.0), f32::INFINITY);
    assert_eq!(exp_fast(f32::MAX), f32::INFINITY);
    assert_eq!(exp_fast(f32::INFINITY), f32::INFINITY);
    assert_eq!(exp_fast(88.301), f32::INFINITY);
    assert!(exp_fast(88.29).is_finite());
    // -overflow: flushes to ~min-normal — strictly positive, never 0
    // or negative, monotone-safe for the silu denominator
    for x in [-87.34f32, -100.0, -1e4, f32::NEG_INFINITY] {
        let y = exp_fast(x);
        assert!(y > 0.0 && y < 1.3e-38, "exp_fast({x}) = {y}");
    }
    // max-ulp sweep vs libm over the satellite's [-87.3, 88.7] band.
    // Inside the clamp ([-87.3, 88.3]) exp_fast must track libm to a
    // few ulp; past 88.3 it deliberately saturates to +inf (asserted
    // exactly), which libm only reaches at 88.7228.
    let (lo, hi) = (-87.3f64, 88.7f64);
    let steps = 200_000usize;
    let mut max_ulp = 0u32;
    let mut worst = 0.0f32;
    for i in 0..=steps {
        let x = (lo + (hi - lo) * i as f64 / steps as f64) as f32;
        let got = exp_fast(x);
        if x > 88.3 {
            assert_eq!(got, f32::INFINITY, "x={x} must saturate");
            continue;
        }
        let want = x.exp(); // libm expf
        assert!(want.is_finite() && want > 0.0);
        // both positive normals: bit distance == ulp distance
        let ulp = want.to_bits().abs_diff(got.to_bits());
        if ulp > max_ulp {
            max_ulp = ulp;
            worst = x;
        }
    }
    assert!(max_ulp <= 16,
            "exp_fast drifted {max_ulp} ulp from libm at x={worst} \
             (contract: ~2 ulp, budget 16)");
}

#[test]
fn native_mlp_gemm_path_matches_scalar_ref() {
    use asd::model::{DenoiseModel, NativeMlp, VariantInfo, Workspace};

    prop::check("mlp-gemm-vs-ref", 15, |g| {
        let d = g.usize_in(1, 6);
        let cond_dim = *g.pick(&[0usize, 3]);
        let hidden = g.usize_in(1, 32);
        let blocks = g.usize_in(0, 3);
        let k_steps = g.usize_in(5, 40);
        let info = VariantInfo::toy("prop", d, cond_dim, hidden, blocks,
                                    k_steps);
        let flat: Vec<f32> = g.normal_vec(info.weights_len())
            .into_iter()
            .map(|v| (v * 0.5) as f32)
            .collect();
        let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
        for n in [0usize, 1, 3, 4, 5, 17] {
            let ys = g.normal_vec(n * d);
            let ts: Vec<f64> =
                (0..n).map(|_| g.usize_in(1, k_steps) as f64).collect();
            let cond = g.normal_vec(n * cond_dim);
            let mut want = vec![0.0; n * d];
            mlp.denoise_batch_ref(&ys, &ts, &cond, n, &mut want).unwrap();
            let mut got = vec![0.0; n * d];
            mlp.denoise_batch(&ys, &ts, &cond, n, &mut got).unwrap();
            for i in 0..n * d {
                let tol = 1e-5 * want[i].abs().max(1.0);
                assert!((want[i] - got[i]).abs() <= tol,
                        "n={n} i={i}: ref {} vs gemm {}", want[i], got[i]);
            }
            // the packed pipeline's in-layer 2-D GEMM tiling must be
            // BIT-identical to the serial packed path (not just within
            // the exp_fast tolerance)
            let mut ws = Workspace::new();
            for shards in [2usize, 8] {
                let mut tiled = vec![0.0; n * d];
                mlp.denoise_batch_tiled(&ys, &ts, &cond, n, &mut tiled,
                                        &mut ws, shards)
                    .unwrap();
                for i in 0..n * d {
                    assert_eq!(got[i].to_bits(), tiled[i].to_bits(),
                               "tiled n={n} shards={shards} i={i}");
                }
            }
        }
    });
}

#[test]
fn sharded_denoise_batch_equals_unsharded_bitwise() {
    use asd::model::{DenoiseModel, Gmm, GmmDdpmOracle, ParallelModel};
    use asd::runtime::pool::PoolConfig;

    prop::check("pool-shard-parity", 20, |g| {
        let d = g.usize_in(1, 8);
        let components = g.usize_in(1, 6);
        let k = 30;
        let oracle =
            GmmDdpmOracle::new(Gmm::random(d, components, 1.2, g.seed),
                               k, false);
        let pool_size = g.usize_in(2, 9);
        let shard_min = g.usize_in(1, 3);
        // odd batch shapes: 1, pool-1, pool+1, and primes
        for n in [1usize, pool_size - 1, pool_size + 1, 7, 13] {
            let n = n.max(1);
            let ys = g.normal_vec(n * d);
            let ts: Vec<f64> =
                (0..n).map(|_| g.usize_in(1, k) as f64).collect();
            let mut want = vec![0.0; n * d];
            oracle.denoise_batch(&ys, &ts, &[], n, &mut want).unwrap();
            let par = ParallelModel::new(
                oracle.clone(), PoolConfig { pool_size, shard_min });
            let mut got = vec![0.0; n * d];
            par.denoise_batch(&ys, &ts, &[], n, &mut got).unwrap();
            let want_bits: Vec<u64> =
                want.iter().map(|v| v.to_bits()).collect();
            let got_bits: Vec<u64> =
                got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(want_bits, got_bits,
                       "n={n} pool={pool_size} shard_min={shard_min} d={d}");
        }
    });
}

#[test]
fn grs_acceptance_counts_invariant_under_pool_and_backend() {
    use asd::asd::{AsdConfig, AsdEngine, KernelBackend};
    use asd::model::{Gmm, GmmDdpmOracle};
    use asd::runtime::pool::PoolConfig;

    // pool-size invariance (always runnable): the verifier consumes the
    // same (u, xi) streams whatever the sharding, so accept/reject
    // counts must match exactly
    for k in [40usize, 90] {
        let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), k, false);
        let mut counts = Vec::new();
        for pool_size in [1usize, 8] {
            let mut e = AsdEngine::new(
                oracle.clone(),
                AsdConfig {
                    theta: 8,
                    pool: PoolConfig { pool_size, shard_min: 1 },
                    ..Default::default()
                });
            let mut acc = 0usize;
            let mut rej = 0usize;
            for seed in 0..5u64 {
                let out = e.sample(seed).unwrap();
                acc += out.stats.accepted;
                rej += out.stats.rejected;
            }
            counts.push((acc, rej));
        }
        assert_eq!(counts[0], counts[1], "K={k}: pool changed GRS counts");
    }

    // kernel-backend invariance (needs compiled HLO kernels; skips
    // cleanly when the artifacts/PJRT runtime is unavailable)
    let Some(rt) = common::try_runtime() else {
        eprintln!("skipping HLO-backend leg: runtime unavailable");
        return;
    };
    let kernels = match rt.kernels(2) {
        Ok(k) => k,
        Err(e) => {
            eprintln!("skipping HLO-backend leg: {e:#}");
            return;
        }
    };
    let model = rt.model("gmm2d").expect("gmm2d variant");
    let mut native = AsdEngine::new(
        model.clone(),
        AsdConfig { theta: 8, ..Default::default() });
    let mut hlo = AsdEngine::new(
        model,
        AsdConfig {
            theta: 8,
            backend: KernelBackend::Hlo(kernels),
            ..Default::default()
        });
    for seed in 0..5u64 {
        let a = native.sample(seed).unwrap();
        let b = hlo.sample(seed).unwrap();
        assert_eq!(a.stats.accepted, b.stats.accepted, "seed {seed}");
        assert_eq!(a.stats.rejected, b.stats.rejected, "seed {seed}");
    }
}

#[test]
fn quantized_packedb_pack_dequant_roundtrip_properties() {
    use asd::math::gemm::{PackedB, KC, NR};
    use asd::math::isa::{f16_to_f32, f32_to_f16, Precision};

    prop::check("quantized-packedb-roundtrip", 30, |g| {
        // shapes straddling the NR column panel and the KC k-panel
        let k = *g.pick(&[1usize, 2, 7, 64, 255, 256, 300]);
        let n = *g.pick(&[1usize, 5, 8, 9, 16, 23]);
        let w: Vec<f32> =
            g.normal_vec(k * n).into_iter().map(|v| v as f32).collect();
        let n_padded = n.div_ceil(NR) * NR;
        for precision in [Precision::F16, Precision::Int8] {
            let pb = PackedB::pack_as(k, n, &w, precision);
            assert_eq!(pb.precision(), precision);
            for p in 0..k {
                // zero-padded tail columns must stay exactly zero
                // after dequant — the kernels accumulate them unmasked
                for j in n..n_padded {
                    assert_eq!(pb.stored(p, j).to_bits(),
                               0.0f32.to_bits(),
                               "padding p={p} j={j} {precision:?}");
                }
                for j in 0..n {
                    let want = w[p * n + j];
                    let got = pb.stored(p, j);
                    match precision {
                        // the panel stores the RNE f16 bit pattern:
                        // round-trip is exact by construction
                        Precision::F16 => assert_eq!(
                            got.to_bits(),
                            f16_to_f32(f32_to_f16(want)).to_bits(),
                            "f16 p={p} j={j}"),
                        // per-(k-panel, column) scale: dequant error
                        // is at most half a quantization step
                        Precision::Int8 => {
                            let p0 = (p / KC) * KC;
                            let pc = KC.min(k - p0);
                            let colmax = (0..pc)
                                .map(|dp| w[(p0 + dp) * n + j].abs())
                                .fold(0.0f32, f32::max);
                            let step = colmax / 127.0;
                            assert!((got - want).abs()
                                        <= step / 2.0 + 1e-6,
                                    "int8 p={p} j={j}: {got} vs {want} \
                                     (step {step})");
                        }
                        Precision::F32 => unreachable!(),
                    }
                }
            }
        }
    });
}

#[test]
fn quantized_mlp_denoise_tracks_scalar_ref_within_documented_bound() {
    use asd::math::isa::{IsaRequest, KernelPolicy, Precision};
    use asd::model::{NativeMlp, VariantInfo};

    // max-relative-error sweep pinning the documented per-tier bound:
    // int8/f16 `denoise_batch` vs the exact-f32 `denoise_batch_ref`
    prop::check("quantized-mlp-error-bound", 8, |g| {
        let d = g.usize_in(1, 5);
        let cond_dim = *g.pick(&[0usize, 2]);
        let hidden = g.usize_in(4, 24);
        let blocks = g.usize_in(0, 2);
        let info = VariantInfo::toy("quant-prop", d, cond_dim, hidden,
                                    blocks, 20);
        let flat: Vec<f32> = g.normal_vec(info.weights_len())
            .into_iter().map(|v| (v * 0.3) as f32).collect();
        for precision in [Precision::F16, Precision::Int8] {
            let policy = KernelPolicy { isa: IsaRequest::Auto, precision };
            let mlp =
                NativeMlp::from_flat_with(&info, &flat, policy).unwrap();
            let tol = policy.denoise_rel_tolerance();
            for n in [1usize, 3, 9] {
                let ys = g.normal_vec(n * d);
                let ts: Vec<f64> =
                    (0..n).map(|_| g.usize_in(1, 20) as f64).collect();
                let cond = g.normal_vec(n * cond_dim);
                let mut want = vec![0.0; n * d];
                mlp.denoise_batch_ref(&ys, &ts, &cond, n, &mut want)
                    .unwrap();
                let mut got = vec![0.0; n * d];
                mlp.denoise_batch(&ys, &ts, &cond, n, &mut got).unwrap();
                let mut max_rel = 0.0f64;
                for i in 0..n * d {
                    let rel = (want[i] - got[i]).abs()
                        / want[i].abs().max(1.0);
                    max_rel = max_rel.max(rel);
                }
                assert!(max_rel <= tol,
                        "{precision:?} n={n}: max rel err {max_rel} \
                         exceeds the documented bound {tol}");
            }
        }
    });
}
