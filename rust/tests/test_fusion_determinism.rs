//! Fused coordinator execution must never change a sampled bit.
//!
//! Extends tests/test_parallel_determinism.rs from the worker-pool
//! layer up to the serving layer: a mixed burst (ASD + Picard +
//! sequential + draft-SD on one variant) served through the
//! coordinator's fused mega-batches must reproduce, bit for bit, the
//! samples each request would get from its solo sampler — at every
//! pool size. This holds because each request's `StepSampler` machine
//! consumes only its own Philox streams and native models are
//! row-independent (`model::parallel`), so fusing rows across requests
//! changes wall-clock, never samples. Draft-SD rides the same
//! argument: the draft chain runs machine-internal, and the target's
//! verify rows are just more rows on the fused round plane.

use std::collections::HashMap;
use std::sync::Arc;

use asd::asd::{AsdConfig, AsdEngine, DraftConfig, DraftEngine};
use asd::coordinator::{Coordinator, Request, SamplerSpec, ServerConfig};
use asd::ddpm::SequentialSampler;
use asd::model::{distill_draft, DenoiseModel, Gmm, GmmDdpmOracle,
                 NativeMlp, VariantInfo};
use asd::picard::{PicardConfig, PicardSampler};
use asd::runtime::pool::PoolConfig;

const POOL_SIZES: [usize; 3] = [1, 2, 8];
const K: usize = 50;

fn model() -> Arc<dyn DenoiseModel> {
    GmmDdpmOracle::new(Gmm::random(8, 6, 1.5, 3), K, false)
}

/// An imperfect draft for [`model`]: the same GMM with component means
/// shifted by 0.05 (alternating sign per coordinate), so the GRS
/// verifier must actually reject some windows — the determinism claim
/// has to survive rejection/resample, not just the all-accept path.
fn draft_model() -> Arc<dyn DenoiseModel> {
    let base = Gmm::random(8, 6, 1.5, 3);
    let means: Vec<Vec<f64>> = (0..base.weights.len())
        .map(|c| {
            base.mean_of(c).iter().enumerate()
                .map(|(i, &v)| {
                    v + 0.05 * if i % 2 == 0 { 1.0 } else { -1.0 }
                })
                .collect()
        })
        .collect();
    let gmm = Gmm::new(means, base.sigmas.clone(), base.weights.clone());
    GmmDdpmOracle::new(gmm, K, false)
}

fn bits(v: &[f64]) -> Vec<u64> {
    asd::math::vec_ops::to_bits_vec(v)
}

/// The burst: 3 of each sampler kind, same specs the coordinator's
/// fusion layer builds machines with.
fn burst_specs() -> Vec<(SamplerSpec, u64)> {
    (0..12u64)
        .map(|i| {
            let spec = match i % 4 {
                0 => SamplerSpec::Sequential,
                1 => SamplerSpec::Asd(8),
                2 => SamplerSpec::Picard(8, 1e-6),
                _ => SamplerSpec::Draft(8),
            };
            (spec, 1000 + i)
        })
        .collect()
}

/// Solo reference sample for one (spec, seed), no coordinator involved.
/// `draft` is only consulted for `SamplerSpec::Draft`.
fn solo_sample(model: &Arc<dyn DenoiseModel>,
               draft: &Arc<dyn DenoiseModel>, spec: SamplerSpec, seed: u64)
               -> Vec<f64> {
    match spec {
        SamplerSpec::Sequential => {
            SequentialSampler::new(model.clone()).sample(seed, &[])
                .unwrap().0
        }
        SamplerSpec::Asd(theta) => {
            let mut e = AsdEngine::new(
                model.clone(), AsdConfig { theta, ..Default::default() });
            e.sample(seed).unwrap().y0
        }
        SamplerSpec::Picard(window, tol) => {
            let p = PicardSampler::new(
                model.clone(),
                PicardConfig { window, tol, max_sweeps: 1000,
                               ..Default::default() });
            p.sample(seed, &[]).unwrap().0
        }
        SamplerSpec::Draft(k) => {
            // same canonical config the coordinator builds machines
            // with (no adaptive controller on served paths)
            let mut e = DraftEngine::new(
                model.clone(), draft.clone(),
                DraftConfig { k, ..Default::default() });
            e.sample(seed).unwrap().y0
        }
    }
}

#[test]
fn fused_mixed_burst_bit_identical_to_solo_across_pool_sizes() {
    let model = model();
    let draft = draft_model();
    let specs = burst_specs();
    let want: Vec<Vec<u64>> = specs.iter()
        .map(|&(spec, seed)| bits(&solo_sample(&model, &draft, spec, seed)))
        .collect();

    for pool_size in POOL_SIZES {
        let c = Coordinator::new(ServerConfig {
            workers: 2,
            max_batch: 16,
            enable_batching: true,
            pool: PoolConfig { pool_size, shard_min: 1 },
            ..Default::default()
        }).unwrap();
        c.register_model("gmm", model.clone());
        c.register_model("gmm-draft", draft.clone());
        c.pair_draft("gmm", "gmm-draft").unwrap();
        let mut rxs = Vec::new();
        for &(spec, seed) in &specs {
            rxs.push(c.submit(Request {
                id: 0,
                variant: "gmm".into(),
                sampler: spec,
                seed,
                cond: vec![],
                deadline: None,
            }).1);
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "pool={pool_size} req {i}: {:?}",
                    r.error);
            assert_eq!(bits(&r.sample), want[i],
                       "pool_size={pool_size} request {i} \
                        ({:?}) changed bits vs solo run", specs[i].0);
        }
        c.shutdown();
    }
}

/// A toy in-memory MLP variant (NativeMlp GEMM backend) for the
/// mixed-variant burst — same layout the benches use, pseudo-random
/// weights, K = 40 — plus a fold-4 draft distilled from its own
/// weights (the native draft/target pairing the serving stack ships).
fn toy_mlp_with_draft() -> (Arc<dyn DenoiseModel>, Arc<dyn DenoiseModel>) {
    let info = VariantInfo::toy("toy", 3, 0, 16, 1, 40);
    let n_w = info.weights_len();
    let flat: Vec<f32> =
        (0..n_w).map(|i| ((i * 37 % 101) as f32 / 101.0) - 0.5).collect();
    let target = NativeMlp::from_flat(&info, &flat).unwrap();
    let (dinfo, dflat) = distill_draft(&info, &flat, 4).unwrap();
    let draft = NativeMlp::from_flat(&dinfo, &dflat).unwrap();
    (target, draft)
}

#[test]
fn mixed_variant_burst_bit_identical_and_both_lanes_fuse() {
    // acceptance criterion: a concurrent two-variant burst (analytic
    // GMM oracle + toy NativeMlp, all four sampler kinds) must be
    // bit-identical to solo execution at pool sizes 1/2/8 — three
    // repetitions each, so a steal-order-dependent bit would have
    // chances to show — AND both variant lanes must fuse rows (no
    // lane served per-request, no cross-variant head-of-line
    // blocking). The NativeMlp lane's fused rounds run as
    // dependency-counted tile graphs on the worker pool (the
    // zero-barrier path), which the pool's tile_tasks counter must
    // witness: graph scheduling freedom, same bits.
    let pool_before = asd::runtime::pool::global_stats();
    let gmm = model();
    let gmm_draft = draft_model();
    let (mlp, mlp_draft) = toy_mlp_with_draft();
    let variants: [(&str, &Arc<dyn DenoiseModel>,
                    &Arc<dyn DenoiseModel>); 2] =
        [("gmm", &gmm, &gmm_draft), ("toy", &mlp, &mlp_draft)];
    // 8 requests per variant, rotating sampler kinds, interleaved
    let burst: Vec<(usize, SamplerSpec, u64)> = (0..16u64)
        .map(|i| {
            let spec = match (i / 2) % 4 {
                0 => SamplerSpec::Sequential,
                1 => SamplerSpec::Asd(8),
                2 => SamplerSpec::Picard(8, 1e-6),
                _ => SamplerSpec::Draft(8),
            };
            ((i % 2) as usize, spec, 3000 + i)
        })
        .collect();
    let want: Vec<Vec<u64>> = burst.iter()
        .map(|&(v, spec, seed)| {
            bits(&solo_sample(variants[v].1, variants[v].2, spec, seed))
        })
        .collect();

    for pool_size in POOL_SIZES {
        for rep in 0..3 {
            let c = Coordinator::new(ServerConfig {
                workers: 2,
                max_batch: 16,
                enable_batching: true,
                pool: PoolConfig { pool_size, shard_min: 1 },
                ..Default::default()
            }).unwrap();
            for (name, m, d) in variants {
                c.register_model(name, (*m).clone());
                let dname = format!("{name}-draft");
                c.register_model(&dname, (*d).clone());
                c.pair_draft(name, &dname).unwrap();
            }
            let rxs: Vec<_> = burst.iter()
                .map(|&(v, spec, seed)| {
                    c.submit(Request {
                        id: 0,
                        variant: variants[v].0.into(),
                        sampler: spec,
                        seed,
                        cond: vec![],
                        deadline: None,
                    }).1
                })
                .collect();
            for (i, rx) in rxs.into_iter().enumerate() {
                let r = rx.recv().unwrap();
                assert!(r.error.is_none(),
                        "pool={pool_size} rep={rep} req {i}: {:?}",
                        r.error);
                assert_eq!(bits(&r.sample), want[i],
                           "pool_size={pool_size} rep={rep} request {i} \
                            (variant {}, {:?}) changed bits vs solo run",
                           variants[burst[i].0].0, burst[i].1);
            }
            let m = c.metrics();
            assert_eq!(m.completed, 16);
            for (name, _, _) in variants {
                let lane = m.lane(name)
                    .unwrap_or_else(|| panic!("no lane '{name}'"));
                assert!(lane.fused_rounds > 0,
                        "pool={pool_size} rep={rep} lane '{name}' never \
                         ran a round");
                assert!(lane.fused_rows_per_round > 1.0,
                        "pool={pool_size} rep={rep} lane '{name}' served \
                         per-request (rows/round {})",
                        lane.fused_rows_per_round);
            }
            c.shutdown();
        }
    }
    // the toy lane's fused rounds went through the tile-graph path:
    // the process-global pool must have executed graph tiles and
    // retired graph rounds on its behalf (counters are cumulative, so
    // compare against the snapshot taken before the bursts)
    let d = asd::runtime::pool::global_stats().since(&pool_before);
    assert!(d.tile_tasks > 0,
            "no graph tiles executed — the NativeMlp lane never took \
             the compiled-round path");
    assert!(d.graph_rounds > 0, "no graph rounds retired");
}

#[test]
fn fused_burst_actually_fuses_rows_per_round() {
    // acceptance criterion: a mixed burst through one worker must be
    // served via fused mega-batches with fused_rows_per_round > 1
    let model = model();
    let c = Coordinator::new(ServerConfig {
        workers: 1,
        max_batch: 16,
        enable_batching: true,
        ..Default::default()
    }).unwrap();
    c.register_model("gmm", model);
    c.register_model("gmm-draft", draft_model());
    c.pair_draft("gmm", "gmm-draft").unwrap();
    let rxs: Vec<_> = burst_specs().into_iter()
        .map(|(spec, seed)| {
            c.submit(Request {
                id: 0,
                variant: "gmm".into(),
                sampler: spec,
                seed,
                cond: vec![],
                deadline: None,
            }).1
        })
        .collect();
    for rx in rxs {
        assert!(rx.recv().unwrap().error.is_none());
    }
    let m = c.metrics();
    assert_eq!(m.completed, 12);
    assert!(m.fused_rounds > 0, "no fused rounds ran");
    assert!(m.fused_rows_per_round > 1.0,
            "fused_rows_per_round {} — burst was served per-request",
            m.fused_rows_per_round);
    c.shutdown();
}

#[test]
fn solo_sized_group_matches_dedicated_engines_repeatedly() {
    // fusion groups of size 1 (requests trickling in) must also stay
    // bit-identical to the engines — the degenerate fused path
    let model = model();
    let draft = draft_model();
    let c = Coordinator::new(ServerConfig {
        workers: 1,
        max_batch: 8,
        enable_batching: true,
        ..Default::default()
    }).unwrap();
    c.register_model("gmm", model.clone());
    c.register_model("gmm-draft", draft.clone());
    c.pair_draft("gmm", "gmm-draft").unwrap();
    for &(spec, seed) in &burst_specs()[..4] {
        let (_, rx) = c.submit(Request {
            id: 0,
            variant: "gmm".into(),
            sampler: spec,
            seed,
            cond: vec![],
            deadline: None,
        });
        // recv before the next submit: each request runs alone
        let r = rx.recv().unwrap();
        assert!(r.error.is_none());
        assert_eq!(bits(&r.sample),
                   bits(&solo_sample(&model, &draft, spec, seed)),
                   "solo-group {spec:?} changed bits");
    }
    c.shutdown();
}

#[test]
fn conditional_requests_fuse_bit_identically() {
    // conditional oracle: every fused row carries its request's own
    // conditioning; scattering must not mix them up
    let model: Arc<dyn DenoiseModel> =
        GmmDdpmOracle::new(Gmm::circle_2d(), 40, true);
    let c_dim = model.cond_dim();
    let mk_cond = |cls: usize| -> Vec<f64> {
        let mut v = vec![0.0; c_dim];
        v[cls % c_dim] = 1.0;
        v
    };
    // solo references
    let mut want: HashMap<u64, Vec<u64>> = HashMap::new();
    for i in 0..6u64 {
        let cond = mk_cond(i as usize);
        let mut e = AsdEngine::new(
            model.clone(), AsdConfig { theta: 6, ..Default::default() });
        want.insert(i, bits(&e.sample_cond(i, &cond).unwrap().y0));
    }
    let c = Coordinator::new(ServerConfig {
        workers: 1,
        max_batch: 8,
        enable_batching: true,
        ..Default::default()
    }).unwrap();
    c.register_model("gmm", model);
    let rxs: Vec<_> = (0..6u64)
        .map(|i| {
            (i, c.submit(Request {
                id: 0,
                variant: "gmm".into(),
                sampler: SamplerSpec::Asd(6),
                seed: i,
                cond: mk_cond(i as usize),
                deadline: None,
            }).1)
        })
        .collect();
    for (i, rx) in rxs {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(&bits(&r.sample), want.get(&i).unwrap(),
                   "request {i}: fused conditioning mismatch");
    }
    c.shutdown();
}
