//! Env parity: the rust point-mass environments must reproduce the
//! python datagen environments bit-for-bit (golden traces from aot.py).

mod common;

use asd::env::{PointMassEnv, TaskSpec};
use common::{approx_eq_slice, golden};

fn replay(task: &str) {
    if common::try_golden().is_none() {
        return;
    }
    let g = golden().get("envs").unwrap().get(task).unwrap();
    let spec = TaskSpec::by_name(task).unwrap();
    let mut env = PointMassEnv::new(spec.clone());

    let init = g.get("init").unwrap();
    let ee: Vec<[f64; 2]> = init.get("ee").unwrap().as_arr().unwrap()
        .iter()
        .map(|r| {
            let v = r.as_f64_vec().unwrap();
            [v[0], v[1]]
        })
        .collect();
    let obj = init.get("obj").unwrap().as_f64_vec().unwrap();
    env.reset_to(&ee, [obj[0], obj[1]]);

    let obs_seq = g.get("obs").unwrap().as_arr().unwrap();
    let actions = g.get("actions").unwrap().as_arr().unwrap();

    approx_eq_slice(&env.obs(), &obs_seq[0].as_f64_vec().unwrap(), 1e-9,
                    &format!("{task} obs[0]"));
    for (t, a) in actions.iter().enumerate() {
        env.step(&a.as_f64_vec().unwrap());
        approx_eq_slice(&env.obs(), &obs_seq[t + 1].as_f64_vec().unwrap(),
                        1e-9, &format!("{task} obs[{}]", t + 1));
    }
    assert_eq!(env.leg_idx as f64,
               g.get("leg_idx").unwrap().as_f64().unwrap(), "{task} leg_idx");
    assert_eq!(env.carried as f64,
               g.get("carried").unwrap().as_f64().unwrap(), "{task} carried");
    assert_eq!(env.failed,
               g.get("failed").unwrap().as_bool().unwrap(), "{task} failed");
}

#[test]
fn square_trace_parity() {
    replay("square");
}

#[test]
fn transport_trace_parity() {
    replay("transport");
}

#[test]
fn toolhang_trace_parity() {
    replay("toolhang");
}

#[test]
fn obs_dims_match_golden() {
    if common::try_golden().is_none() {
        return;
    }
    let envs = golden().get("envs").unwrap().as_obj().unwrap();
    for (task, g) in envs {
        let spec = TaskSpec::by_name(task).unwrap();
        assert_eq!(spec.obs_dim() as f64,
                   g.get("obs_dim").unwrap().as_f64().unwrap(), "{task}");
        assert_eq!(spec.action_dim() as f64,
                   g.get("action_dim").unwrap().as_f64().unwrap(), "{task}");
    }
}
