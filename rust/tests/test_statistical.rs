//! Statistical integration tests for the paper's core claims, run with
//! the analytic GMM oracle (no network error):
//!
//! * Thm 3: ASD output law == sequential DDPM output law (two-sample KS
//!   per coordinate + radial statistic).
//! * Thm 3 / Lemma 13 for draft-model speculation: draft-SD output law
//!   == sequential DDPM output law even under an imperfect draft (the
//!   GRS verifier corrects the draft's proposal bias exactly).
//! * Thm 1: SL increments are exchangeable (moment symmetry).
//! * Thm 12: GRS rejection rate equals the Gaussian TV distance
//!   (swept over ||v||/sigma by the property harness).

mod common;

use asd::asd::{grs_native, AsdConfig, AsdEngine, DraftConfig, DraftEngine,
               KernelBackend};
use asd::ddpm::SequentialSampler;
use asd::math::erf::gaussian_tv;
use asd::math::stats::{ks_critical, ks_statistic};
use asd::model::{Gmm, GmmDdpmOracle};
use asd::rng::Philox;

#[test]
fn asd_law_equals_sequential_law_ks() {
    let k = 60;
    let oracle = GmmDdpmOracle::new(Gmm::circle_2d(), k, false);
    let seq = SequentialSampler::new(oracle.clone());
    let mut engine = AsdEngine::new(
        oracle,
        AsdConfig { theta: 8, eval_tail: true, backend: KernelBackend::Native,
                    ..Default::default() });
    let n = 500;
    let mut seq_x = Vec::with_capacity(n);
    let mut seq_r = Vec::with_capacity(n);
    let mut asd_x = Vec::with_capacity(n);
    let mut asd_r = Vec::with_capacity(n);
    for s in 0..n as u64 {
        let (y, _) = seq.sample(s, &[]).unwrap();
        seq_x.push(y[0]);
        seq_r.push((y[0] * y[0] + y[1] * y[1]).sqrt());
        let out = engine.sample(1_000_000 + s).unwrap();
        asd_x.push(out.y0[0]);
        asd_r.push((out.y0[0].powi(2) + out.y0[1].powi(2)).sqrt());
    }
    let crit = ks_critical(n, n, 0.001);
    let d_x = ks_statistic(&seq_x, &asd_x);
    let d_r = ks_statistic(&seq_r, &asd_r);
    assert!(d_x < crit, "x-coordinate KS {d_x} >= {crit}");
    assert!(d_r < crit, "radius KS {d_r} >= {crit}");
}

#[test]
fn draft_sd_law_equals_sequential_law_ks() {
    // draft-model speculative sampling with a deliberately WRONG draft
    // (component means shifted by 0.05, alternating sign) must still
    // reproduce the sequential DDPM law exactly: the target's GRS
    // verifier accepts/resamples so the draft only affects round
    // counts, never the output distribution.
    let k = 60;
    let eps = 0.05;
    let gmm = Gmm::circle_2d();
    let comps = gmm.weights.len();
    let shifted: Vec<Vec<f64>> = (0..comps)
        .map(|c| {
            gmm.mean_of(c).iter().enumerate()
                .map(|(i, &v)| {
                    v + eps * if i % 2 == 0 { 1.0 } else { -1.0 }
                })
                .collect()
        })
        .collect();
    let draft_gmm = Gmm::new(shifted, gmm.sigmas.clone(),
                             gmm.weights.clone());
    let target = GmmDdpmOracle::new(gmm, k, false);
    let draft = GmmDdpmOracle::new(draft_gmm, k, false);
    let seq = SequentialSampler::new(target.clone());
    let mut engine = DraftEngine::new(
        target, draft, DraftConfig { k: 8, ..Default::default() });
    let n = 500;
    let mut seq_x = Vec::with_capacity(n);
    let mut seq_r = Vec::with_capacity(n);
    let mut dsd_x = Vec::with_capacity(n);
    let mut dsd_r = Vec::with_capacity(n);
    let mut accepted = 0usize;
    let mut rejected = 0usize;
    for s in 0..n as u64 {
        let (y, _) = seq.sample(s, &[]).unwrap();
        seq_x.push(y[0]);
        seq_r.push((y[0] * y[0] + y[1] * y[1]).sqrt());
        let out = engine.sample(1_000_000 + s).unwrap();
        dsd_x.push(out.y0[0]);
        dsd_r.push((out.y0[0].powi(2) + out.y0[1].powi(2)).sqrt());
        accepted += out.stats.accepted;
        rejected += out.stats.rejected;
    }
    // the imperfect draft must actually get rejected sometimes (else
    // this leg degenerates to the v=0 bit-identity invariant) while
    // still being useful (accept rate well above chance)
    assert!(rejected > 0, "eps={eps} draft was never rejected");
    let acc_rate = accepted as f64 / (accepted + rejected) as f64;
    assert!(acc_rate > 0.5, "draft acceptance collapsed: {acc_rate}");
    let crit = ks_critical(n, n, 0.001);
    let d_x = ks_statistic(&seq_x, &dsd_x);
    let d_r = ks_statistic(&seq_r, &dsd_r);
    assert!(d_x < crit, "x-coordinate KS {d_x} >= {crit}");
    assert!(d_r < crit, "radius KS {d_r} >= {crit}");
}

#[test]
fn sl_increments_are_exchangeable() {
    // ybar_t = t x* + W_t with x* ~ Rademacher; equal-eta increments
    // Delta_i = eta x* + sqrt(eta) N(0,1): permutation-invariant moments
    let mut rng = Philox::new(5, 0);
    let n = 60_000;
    let m = 4;
    let eta: f64 = 0.25;
    let mut deltas = vec![0.0; n * m];
    for r in 0..n {
        let x_star = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        for j in 0..m {
            deltas[r * m + j] = eta * x_star + eta.sqrt() * rng.normal();
        }
    }
    let pair_moment = |a: usize, b: usize| -> f64 {
        (0..n).map(|r| deltas[r * m + a] * deltas[r * m + b]).sum::<f64>()
            / n as f64
    };
    let tol = 4.0 / (n as f64).sqrt();
    let m01 = pair_moment(0, 1);
    assert!((m01 - pair_moment(1, 2)).abs() < tol);
    assert!((m01 - pair_moment(0, 3)).abs() < tol);
    // marginals match too
    let col = |j: usize| -> Vec<f64> {
        (0..n).map(|r| deltas[r * m + j]).collect()
    };
    let d = ks_statistic(&col(0), &col(3));
    assert!(d < ks_critical(n, n, 0.001), "KS {d}");
}

#[test]
fn grs_rejection_rate_equals_tv_sweep() {
    // property-style sweep over v and sigma
    asd::util::prop::check("grs-tv", 6, |g| {
        let d = g.usize_in(1, 8);
        let sigma = g.f64_in(0.2, 1.5);
        let mut m_hat = vec![0.0; d];
        m_hat[0] = g.f64_in(0.0, 2.0);
        let m = vec![0.0; d];
        let n = 12_000;
        let mut rejects = 0usize;
        let mut z = vec![0.0; d];
        let mut v = vec![0.0; d];
        for _ in 0..n {
            let xi: Vec<f64> = (0..d).map(|_| g.rng.normal()).collect();
            let u = g.rng.uniform();
            if !grs_native(u, &xi, &m_hat, &m, sigma, &mut z, &mut v) {
                rejects += 1;
            }
        }
        let want = gaussian_tv(m_hat[0], sigma);
        let got = rejects as f64 / n as f64;
        assert!((got - want).abs() < 0.02,
                "reject {got} vs TV {want} (v={}, sigma={sigma})", m_hat[0]);
    });
}

#[test]
fn round_latency_monotone_non_increasing_in_pool_size() {
    // Statistical claim behind the pool substrate: on a fixed heavy GMM
    // workload, the measured latency of batched verify rounds must not
    // grow with pool_size. Generous tolerance (wall-clock on shared CI
    // boxes is noisy and other tests run concurrently): each sharded
    // config may be at most 2x the serial baseline plus a 200us grace;
    // we do NOT require strict speedup, only "sharding never makes
    // rounds meaningfully slower".
    use std::sync::Arc;

    use asd::model::DenoiseModel;
    use asd::runtime::pool::PoolConfig;

    let model: Arc<dyn DenoiseModel> =
        GmmDdpmOracle::new(Gmm::random(64, 96, 1.5, 11), 100, false);
    let pool_sizes = [1usize, 2, 4];
    let mut latency = Vec::new();
    for &pool_size in &pool_sizes {
        let mut engine = AsdEngine::new(
            model.clone(),
            AsdConfig {
                theta: 16,
                pool: PoolConfig { pool_size, shard_min: 2 },
                ..Default::default()
            });
        // warm up pool workers and caches off the record
        engine.sample(0).unwrap();
        // take the MINIMUM per-sample mean across seeds: parallel test
        // neighbors inflate individual measurements, and the min keeps
        // the quiet-window reading, which is what the claim is about
        let mut best = f64::INFINITY;
        for seed in 1..=5u64 {
            let out = engine.sample(seed).unwrap();
            let mut total = 0.0;
            let mut rounds = 0usize;
            for (i, &lat) in out.stats.round_latency_s.iter().enumerate() {
                // only big verify rounds — the ones sharding targets
                if out.stats.round_batches[i] >= 8 {
                    total += lat;
                    rounds += 1;
                }
            }
            assert!(rounds > 0, "workload produced no batched rounds");
            best = best.min(total / rounds as f64);
        }
        latency.push(best);
    }
    let base = latency[0];
    for (i, &lat) in latency.iter().enumerate().skip(1) {
        assert!(lat <= base * 2.0 + 200e-6,
                "pool_size={} mean batched-round latency {:.1}us vs \
                 serial {:.1}us — sharding made rounds slower",
                pool_sizes[i], lat * 1e6, base * 1e6);
    }
}

#[test]
fn conditional_oracle_asd_respects_conditioning() {
    // conditioned on class c, both samplers land near mu_c
    let k = 60;
    let gmm = Gmm::circle_2d();
    let mu3 = gmm.mean_of(3).to_vec();
    let oracle = GmmDdpmOracle::new(gmm, k, true);
    let mut cond = vec![0.0; 8];
    cond[3] = 1.0;
    let mut engine = AsdEngine::new(
        oracle,
        AsdConfig { theta: 8, eval_tail: true, backend: KernelBackend::Native,
                    ..Default::default() });
    for s in 0..30 {
        let out = engine.sample_cond(s, &cond).unwrap();
        let dist = ((out.y0[0] - mu3[0]).powi(2)
            + (out.y0[1] - mu3[1]).powi(2)).sqrt();
        assert!(dist < 0.12 * 6.0, "seed {s}: {dist}");
    }
}
