//! Coordinator end-to-end over real HLO models: concurrent requests,
//! mixed samplers, dynamic batching, failure handling.

mod common;

use asd::coordinator::{Coordinator, Request, SamplerSpec, ServerConfig};
use common::runtime;

fn coordinator() -> Coordinator {
    let rt = runtime();
    let c = Coordinator::new(ServerConfig {
        workers: 2,
        max_batch: 4,
        enable_batching: true,
        ..Default::default()
    }).unwrap();
    c.register_model("gmm2d", rt.model("gmm2d").unwrap());
    c
}

fn req(sampler: SamplerSpec, seed: u64) -> Request {
    Request { id: 0, variant: "gmm2d".into(), sampler, seed, cond: vec![],
              deadline: None }
}

#[test]
fn mixed_workload_completes() {
    if common::try_runtime().is_none() {
        return;
    }
    let c = coordinator();
    let mut rxs = Vec::new();
    for i in 0..12u64 {
        let sampler = match i % 3 {
            0 => SamplerSpec::Sequential,
            1 => SamplerSpec::Asd(8),
            _ => SamplerSpec::Picard(8, 1e-4),
        };
        rxs.push(c.submit(req(sampler, i)).1);
    }
    let mut ok = 0;
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.sample.len(), 2);
        // samples land near the circle target (radius 1.5 +- slack)
        let radius = (r.sample[0].powi(2) + r.sample[1].powi(2)).sqrt();
        assert!((0.5..3.0).contains(&radius), "radius {radius}");
        ok += 1;
    }
    assert_eq!(ok, 12);
    let m = c.metrics();
    assert_eq!(m.completed, 12);
    assert!(m.model_calls > 0);
    c.shutdown();
}

#[test]
fn asd_requests_report_fewer_rounds_than_sequential() {
    if common::try_runtime().is_none() {
        return;
    }
    let c = coordinator();
    let (_, rx_seq) = c.submit(req(SamplerSpec::Sequential, 77));
    let (_, rx_asd) = c.submit(req(SamplerSpec::Asd(8), 77));
    let r_seq = rx_seq.recv().unwrap();
    let r_asd = rx_asd.recv().unwrap();
    assert_eq!(r_seq.parallel_rounds, 100);
    assert!(r_asd.parallel_rounds < 50,
            "asd rounds {}", r_asd.parallel_rounds);
    let st = r_asd.asd_stats.unwrap();
    assert!(st.acceptance_rate() > 0.8);
    c.shutdown();
}

#[test]
fn unknown_variant_fails_without_poisoning_the_pool() {
    if common::try_runtime().is_none() {
        return;
    }
    let c = coordinator();
    let (_, bad) = c.submit(Request {
        id: 0,
        variant: "missing".into(),
        sampler: SamplerSpec::Sequential,
        seed: 0,
        cond: vec![],
        deadline: None,
    });
    assert!(bad.recv().unwrap().error.is_some());
    // pool still serves
    let (_, good) = c.submit(req(SamplerSpec::Sequential, 1));
    assert!(good.recv().unwrap().error.is_none());
    c.shutdown();
}
