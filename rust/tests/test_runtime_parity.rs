//! Runtime integration: the AOT HLO executables must agree with (a) the
//! golden forwards computed by the python L2 model and (b) the
//! rust-native MLP oracle, across every variant and batch size.

mod common;

use asd::model::{DenoiseModel, NativeMlp};
use common::{approx_eq_slice, golden};

fn golden_cases(variant: &str) -> Vec<(Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>)> {
    let cases = golden()
        .get("model_forwards").unwrap()
        .get(variant).unwrap()
        .as_arr().unwrap();
    cases
        .iter()
        .map(|c| {
            let flat2 = |key: &str| -> Vec<f64> {
                c.get(key).unwrap().as_arr().unwrap()
                    .iter()
                    .flat_map(|row| row.as_f64_vec().unwrap())
                    .collect()
            };
            (
                flat2("y"),
                c.get("t").unwrap().as_f64_vec().unwrap(),
                flat2("cond"),
                flat2("x0"),
            )
        })
        .collect()
}

fn check_variant_against_golden(variant: &str) {
    let Some(rt) = common::try_runtime() else { return };
    if common::try_golden().is_none() {
        return;
    }
    let hlo = rt.model(variant).expect("load model");
    let info = rt.manifest.variant(variant).unwrap();
    let native = NativeMlp::load(info, &rt.manifest.dir).unwrap();
    let d = info.d;
    for (case_idx, (y, t, cond, want)) in golden_cases(variant).iter().enumerate() {
        let n = t.len();
        let mut out_hlo = vec![0.0; n * d];
        hlo.denoise_batch(y, t, cond, n, &mut out_hlo).unwrap();
        approx_eq_slice(&out_hlo, want, 2e-4,
                        &format!("{variant} case {case_idx} (hlo vs golden)"));
        let mut out_native = vec![0.0; n * d];
        native.denoise_batch(y, t, cond, n, &mut out_native).unwrap();
        approx_eq_slice(&out_native, want, 2e-4,
                        &format!("{variant} case {case_idx} (native vs golden)"));
    }
}

#[test]
fn gmm2d_forward_parity() {
    check_variant_against_golden("gmm2d");
}

#[test]
fn latent16_forward_parity() {
    check_variant_against_golden("latent16");
}

#[test]
fn pixel64_forward_parity() {
    check_variant_against_golden("pixel64");
}

#[test]
fn policy_forwards_parity() {
    check_variant_against_golden("policy_square");
    check_variant_against_golden("policy_transport");
    check_variant_against_golden("policy_toolhang");
}

#[test]
fn batch_padding_and_chunking_consistent() {
    // results must be independent of which compiled batch size serves a
    // row: run n=1, n=3 (padded to 4), n=33 (chunked 32+1) and compare
    let Some(rt) = common::try_runtime() else { return };
    let model = rt.model("gmm2d").unwrap();
    let d = model.dim();
    let n = 33;
    let ys: Vec<f64> = (0..n * d).map(|i| ((i * 31 % 17) as f64 - 8.0) / 5.0).collect();
    let ts: Vec<f64> = (0..n).map(|i| (1 + (i * 7) % 100) as f64).collect();
    let mut all = vec![0.0; n * d];
    model.denoise_batch(&ys, &ts, &[], n, &mut all).unwrap();
    for r in [0usize, 2, 31, 32] {
        let mut one = vec![0.0; d];
        model.denoise_batch(&ys[r * d..(r + 1) * d], &ts[r..r + 1], &[], 1,
                            &mut one).unwrap();
        approx_eq_slice(&all[r * d..(r + 1) * d], &one, 1e-5,
                        &format!("row {r}"));
    }
}

#[test]
fn schedule_matches_golden_spots() {
    if common::try_golden().is_none() {
        return;
    }
    let g = golden().get("schedule").unwrap();
    for k in [100usize, 1000] {
        let s = asd::schedule::DdpmSchedule::new(k);
        let spot = g.get(&k.to_string()).unwrap();
        let idx: Vec<usize> = spot.get("idx").unwrap().as_f64_vec().unwrap()
            .iter().map(|&x| x as usize).collect();
        for (slot, &i) in idx.iter().enumerate() {
            for (field, arr) in [("c1", &s.c1), ("c2", &s.c2),
                                 ("sigma", &s.sigma), ("abar", &s.abar)] {
                let want = spot.get(field).unwrap().as_arr().unwrap()[slot]
                    .as_f64().unwrap();
                let got = arr[i];
                assert!((got - want).abs() < 1e-9,
                        "K={k} {field}[{i}]: {got} vs {want}");
            }
        }
    }
}

#[test]
fn manifest_abar_matches_rust_schedule() {
    let Some(rt) = common::try_runtime() else { return };
    for (name, v) in &rt.manifest.variants {
        let s = asd::schedule::DdpmSchedule::new(v.k_steps);
        for (i, &a) in v.abar.iter().enumerate() {
            assert!((s.abar[i] - a).abs() < 1e-9,
                    "{name} abar[{i}]: {} vs {a}", s.abar[i]);
        }
    }
}

#[test]
fn hlo_kernels_match_native() {
    // speculate + verify HLO kernels vs the engine's native math
    let Some(rt) = common::try_runtime() else { return };
    let kernels = rt.kernels(2).unwrap();
    let d = 2;
    let t = 5;
    let y_a = vec![0.3, -0.8];
    let x0a = vec![1.2, 0.4];
    let c1: Vec<f64> = (0..t).map(|i| 0.01 * (i + 1) as f64).collect();
    let c2: Vec<f64> = (0..t).map(|i| 1.0 - 0.005 * (i + 1) as f64).collect();
    let sigma: Vec<f64> = (0..t).map(|i| 0.05 * (i + 1) as f64).collect();
    let xi: Vec<f64> = (0..t * d).map(|i| ((i as f64) * 0.37).sin()).collect();

    let (m_hlo, y_hlo) = kernels.speculate(&y_a, &x0a, &c1, &c2, &sigma, &xi)
        .unwrap();
    // native recurrence
    let mut m_nat = vec![0.0; t * d];
    let mut y_nat = vec![0.0; t * d];
    let mut prev = y_a.clone();
    for k in 0..t {
        for i in 0..d {
            m_nat[k * d + i] = c1[k] * x0a[i] + c2[k] * prev[i];
            y_nat[k * d + i] = m_nat[k * d + i] + sigma[k] * xi[k * d + i];
        }
        prev = y_nat[k * d..(k + 1) * d].to_vec();
    }
    approx_eq_slice(&m_hlo, &m_nat, 1e-4, "speculate m_hat");
    approx_eq_slice(&y_hlo, &y_nat, 1e-4, "speculate y_hat");

    // verify kernel vs native GRS
    let u: Vec<f64> = (0..t).map(|i| 0.1 + 0.18 * i as f64).collect();
    let m_tgt: Vec<f64> = m_nat.iter().map(|&x| x + 0.2).collect();
    let (z_hlo, acc_hlo) = kernels.verify(&u, &xi, &m_nat, &m_tgt, &sigma)
        .unwrap();
    let mut z_buf = vec![0.0; d];
    let mut v_buf = vec![0.0; d];
    for k in 0..t {
        let ok = asd::asd::grs_native(
            u[k], &xi[k * d..(k + 1) * d], &m_nat[k * d..(k + 1) * d],
            &m_tgt[k * d..(k + 1) * d], sigma[k], &mut z_buf, &mut v_buf);
        assert_eq!(ok, acc_hlo[k], "accept flag row {k}");
        approx_eq_slice(&z_hlo[k * d..(k + 1) * d], &z_buf, 1e-3,
                        &format!("verify z row {k}"));
    }
}
