//! Failure injection: malformed artifacts, truncated weights, bad
//! requests — errors must surface cleanly and never poison the device
//! thread or the worker pool.

mod common;

use std::io::Write;

use asd::model::{Manifest, NativeMlp};
use asd::runtime::HloModel;


#[test]
fn malformed_hlo_artifact_reports_error_and_device_survives() {
    let Some(rt) = common::try_runtime() else { return };
    let dir = std::env::temp_dir().join("asd_bad_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.hlo.txt");
    let mut f = std::fs::File::create(&bad).unwrap();
    writeln!(f, "HloModule this is not {{ valid").unwrap();
    let err = rt.device.compile(bad, "bad").unwrap_err().to_string();
    assert!(!err.is_empty());
    // device thread still serves real work afterwards
    let model = rt.model("gmm2d").unwrap();
    let mut out = vec![0.0; 2];
    use asd::model::DenoiseModel;
    model.denoise_batch(&[0.1, 0.2], &[50.0], &[], 1, &mut out).unwrap();
    assert!(out.iter().all(|v| v.is_finite()));
}

#[test]
fn missing_artifact_file_is_a_clean_error() {
    let Some(rt) = common::try_runtime() else { return };
    let mut info = rt.manifest.variant("gmm2d").unwrap().clone();
    info.weights_file = "does_not_exist.bin".into();
    let err = HloModel::load(&rt.device, info, &rt.manifest.dir);
    assert!(err.is_err());
}

#[test]
fn truncated_weights_rejected_by_native_and_hlo_loaders() {
    let Some(rt) = common::try_runtime() else { return };
    let dir = std::env::temp_dir().join("asd_trunc_weights");
    std::fs::create_dir_all(&dir).unwrap();
    let mut info = rt.manifest.variant("gmm2d").unwrap().clone();
    // write a too-short weights file
    std::fs::write(dir.join(&info.weights_file), [0u8; 64]).unwrap();
    assert!(NativeMlp::load(&info, &dir).is_err());
    info.weights_file = info.weights_file.clone();
    assert!(HloModel::load(&rt.device, info, &dir).is_err());
}

#[test]
fn manifest_with_missing_keys_is_rejected() {
    let j = asd::util::Json::parse(r#"{"format_version": 1, "variants": {
        "x": {"d": 2}}, "kernels": {"speculate": {}, "verify": {}},
        "beta_start": 0.1, "beta_end": 0.2, "spec_t": 32, "chunk": 16,
        "exec_steps": 8}"#).unwrap();
    // direct path: full parse via Manifest requires all fields; simulate
    // by writing to a temp dir
    let dir = std::env::temp_dir().join("asd_bad_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), j.to_string()).unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("variant 'x'") || err.contains("missing key"),
            "{err}");
}

#[test]
fn wrong_format_version_rejected() {
    let dir = std::env::temp_dir().join("asd_bad_version");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"),
                   r#"{"format_version": 99}"#).unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("format_version"), "{err}");
}

#[test]
fn batch_larger_than_compiled_sizes_chunks_not_fails() {
    use asd::model::DenoiseModel;
    let Some(rt) = common::try_runtime() else { return };
    let model = rt.model("gmm2d").unwrap();
    let n = 70; // > max batch 32 -> 3 chunks
    let ys = vec![0.0; n * 2];
    let ts = vec![1.0; n];
    let mut out = vec![0.0; n * 2];
    model.denoise_batch(&ys, &ts, &[], n, &mut out).unwrap();
    assert!(out.iter().all(|v| v.is_finite()));
}
