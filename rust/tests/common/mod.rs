//! Shared helpers for the integration tests.
//!
//! Hardening rule: tests that need the AOT artifacts (golden.json,
//! manifest + weights) or a live PJRT backend must *skip* — not fail —
//! when those are absent. The artifacts are produced by the python L2
//! pipeline (`make artifacts`, needs JAX) and the PJRT backend by the
//! real `xla` bindings; neither exists in a pure-rust checkout, where
//! the suite still exercises every native substrate.

use std::path::PathBuf;
use std::sync::OnceLock;

use asd::runtime::Runtime;
use asd::util::Json;

#[allow(dead_code)]
pub fn artifacts_dir() -> PathBuf {
    asd::artifacts_dir()
}

/// Golden traces exported by aot.py, or `None` when absent (callers
/// early-return to skip). Logged once per test binary.
#[allow(dead_code)]
pub fn try_golden() -> Option<&'static Json> {
    static GOLDEN: OnceLock<Option<Json>> = OnceLock::new();
    GOLDEN
        .get_or_init(|| {
            let path = artifacts_dir().join("golden.json");
            match Json::parse_file(&path) {
                Ok(j) => Some(j),
                Err(e) => {
                    eprintln!("skipping golden-trace tests: {e:#} \
                               (run `make artifacts` to enable)");
                    None
                }
            }
        })
        .as_ref()
}

/// Golden traces; only call after a successful [`try_golden`] guard.
#[allow(dead_code)]
pub fn golden() -> &'static Json {
    try_golden().expect("golden.json — run `make artifacts` first")
}

/// One shared Runtime per test binary (PJRT init is expensive; the
/// device thread serializes executions anyway), or `None` when the
/// artifacts or the PJRT backend are unavailable.
#[allow(dead_code)]
pub fn try_runtime() -> Option<&'static Runtime> {
    static RT: OnceLock<Option<Runtime>> = OnceLock::new();
    RT.get_or_init(|| match Runtime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping PJRT-dependent tests: {e:#}");
            None
        }
    })
    .as_ref()
}

/// The shared Runtime; only call after a successful [`try_runtime`]
/// guard.
#[allow(dead_code)]
pub fn runtime() -> &'static Runtime {
    try_runtime().expect("runtime unavailable — artifacts/PJRT missing")
}

#[allow(dead_code)]
pub fn approx_eq_slice(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                "{what}[{i}]: {x} vs {y}");
    }
}
