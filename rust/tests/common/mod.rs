//! Shared helpers for the integration tests.

use std::path::PathBuf;
use std::sync::OnceLock;

use asd::runtime::Runtime;
use asd::util::Json;

pub fn artifacts_dir() -> PathBuf {
    asd::artifacts_dir()
}

/// Golden traces exported by aot.py (env traces, model forwards,
/// schedule spots, ASD trace).
pub fn golden() -> &'static Json {
    static GOLDEN: OnceLock<Json> = OnceLock::new();
    GOLDEN.get_or_init(|| {
        Json::parse_file(&artifacts_dir().join("golden.json"))
            .expect("golden.json — run `make artifacts` first")
    })
}

/// One shared Runtime per test binary (PJRT init is expensive; the
/// device thread serializes executions anyway).
pub fn runtime() -> &'static Runtime {
    static RT: OnceLock<Runtime> = OnceLock::new();
    RT.get_or_init(|| Runtime::load_default().expect("runtime"))
}

pub fn approx_eq_slice(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                "{what}[{i}]: {x} vs {y}");
    }
}
