//! Sharded execution must never change a sampled bit.
//!
//! The worker pool splits batched denoise calls into contiguous row
//! shards; each row's float summation order stays inside the inner
//! model, so for any `pool_size` the ASD engine, the Picard sampler and
//! the lockstep batched sampler must reproduce the `pool_size = 1`
//! outputs exactly (same Philox streams, same bits) — together with all
//! accept/reject bookkeeping.

use std::sync::Arc;

use asd::asd::{AsdConfig, AsdEngine};
use asd::ddpm::{BatchedSequentialSampler, NoiseStreams, SequentialSampler};
use asd::model::{DenoiseModel, Gmm, GmmDdpmOracle};
use asd::picard::{PicardConfig, PicardSampler};
use asd::runtime::pool::PoolConfig;

const POOL_SIZES: [usize; 3] = [1, 2, 8];

fn heavy_oracle(d: usize, components: usize, k: usize)
                -> Arc<dyn DenoiseModel> {
    GmmDdpmOracle::new(Gmm::random(d, components, 1.5, 3), k, false)
}

fn bits(v: &[f64]) -> Vec<u64> {
    asd::math::vec_ops::to_bits_vec(v)
}

#[test]
fn asd_bit_identical_across_pool_sizes() {
    let model = heavy_oracle(16, 12, 80);
    let mut reference: Option<(Vec<u64>, usize, usize, usize)> = None;
    for pool_size in POOL_SIZES {
        let mut engine = AsdEngine::new(
            model.clone(),
            AsdConfig {
                theta: 8,
                pool: PoolConfig { pool_size, shard_min: 1 },
                ..Default::default()
            });
        let mut all_bits = Vec::new();
        let mut rounds = 0usize;
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        for seed in 0..6u64 {
            let out = engine.sample(seed).unwrap();
            all_bits.extend(bits(&out.y0));
            rounds += out.stats.parallel_rounds;
            accepted += out.stats.accepted;
            rejected += out.stats.rejected;
            // bookkeeping invariants hold under sharding too
            assert_eq!(out.stats.round_shards.len(),
                       out.stats.parallel_rounds);
            assert_eq!(out.stats.round_latency_s.len(),
                       out.stats.parallel_rounds);
        }
        match &reference {
            None => reference = Some((all_bits, rounds, accepted, rejected)),
            Some((b, r, a, j)) => {
                assert_eq!(&all_bits, b,
                           "pool_size={pool_size} changed output bits");
                assert_eq!(rounds, *r, "pool_size={pool_size} rounds");
                assert_eq!(accepted, *a, "pool_size={pool_size} accepts");
                assert_eq!(rejected, *j, "pool_size={pool_size} rejects");
            }
        }
    }
}

#[test]
fn asd_theta_infinity_bit_identical_across_pool_sizes() {
    // ASD-inf produces the largest verify batches — the heaviest
    // sharding pattern
    let model = heavy_oracle(8, 6, 100);
    let mut reference: Option<Vec<u64>> = None;
    for pool_size in POOL_SIZES {
        let mut engine = AsdEngine::new(
            model.clone(),
            AsdConfig {
                theta: 0,
                pool: PoolConfig { pool_size, shard_min: 2 },
                ..Default::default()
            });
        let mut all_bits = Vec::new();
        for seed in 20..24u64 {
            all_bits.extend(bits(&engine.sample(seed).unwrap().y0));
        }
        match &reference {
            None => reference = Some(all_bits),
            Some(b) => assert_eq!(&all_bits, b, "pool_size={pool_size}"),
        }
    }
}

#[test]
fn picard_bit_identical_across_pool_sizes() {
    let model = heavy_oracle(16, 12, 60);
    let mut reference: Option<(Vec<u64>, usize)> = None;
    for pool_size in POOL_SIZES {
        let sampler = PicardSampler::new(
            model.clone(),
            PicardConfig {
                window: 8,
                tol: 1e-8,
                max_sweeps: 400,
                pool: PoolConfig { pool_size, shard_min: 1 },
            });
        let mut all_bits = Vec::new();
        let mut rounds = 0usize;
        for seed in 0..4u64 {
            let noise = NoiseStreams::draw(seed, 0, 60, 16);
            let (y0, st) = sampler.sample_with_noise(&noise, &[]).unwrap();
            all_bits.extend(bits(&y0));
            rounds += st.parallel_rounds;
        }
        match &reference {
            None => reference = Some((all_bits, rounds)),
            Some((b, r)) => {
                assert_eq!(&all_bits, b,
                           "pool_size={pool_size} changed Picard bits");
                assert_eq!(rounds, *r, "pool_size={pool_size} rounds");
            }
        }
    }
}

#[test]
fn batched_sequential_bit_identical_across_pool_sizes() {
    let model = heavy_oracle(16, 12, 40);
    // odd chain count on purpose: uneven shards
    let seeds: Vec<u64> = (0..7).collect();
    let mut reference: Option<Vec<u64>> = None;
    for pool_size in POOL_SIZES {
        let sampler = BatchedSequentialSampler::with_pool(
            model.clone(), PoolConfig { pool_size, shard_min: 1 });
        let (ys, st) = sampler.sample_batch(&seeds, &[]).unwrap();
        assert_eq!(st.model_calls, 40);
        let b = bits(&ys);
        match &reference {
            None => reference = Some(b),
            Some(want) => assert_eq!(&b, want, "pool_size={pool_size}"),
        }
    }
    // and the sharded lockstep result still matches per-request
    // sampling (tolerance as in the seed's batched_matches_individual)
    let per_request = SequentialSampler::new(model.clone());
    let pooled = BatchedSequentialSampler::with_pool(
        model, PoolConfig { pool_size: 8, shard_min: 1 });
    let (ys, _) = pooled.sample_batch(&seeds, &[]).unwrap();
    let d = 16;
    for (r, &seed) in seeds.iter().enumerate() {
        let (one, _) = per_request.sample(seed, &[]).unwrap();
        for i in 0..d {
            assert!((one[i] - ys[r * d + i]).abs() < 1e-9,
                    "row {r} dim {i}");
        }
    }
}

#[test]
fn conditional_asd_bit_identical_across_pool_sizes() {
    let model: Arc<dyn DenoiseModel> =
        GmmDdpmOracle::new(Gmm::circle_2d(), 60, true);
    let mut cond = vec![0.0; 8];
    cond[5] = 1.0;
    let mut reference: Option<Vec<u64>> = None;
    for pool_size in POOL_SIZES {
        let mut engine = AsdEngine::new(
            model.clone(),
            AsdConfig {
                theta: 8,
                pool: PoolConfig { pool_size, shard_min: 1 },
                ..Default::default()
            });
        let mut all_bits = Vec::new();
        for seed in 0..4u64 {
            all_bits.extend(bits(&engine.sample_cond(seed, &cond)
                                 .unwrap().y0));
        }
        match &reference {
            None => reference = Some(all_bits),
            Some(b) => assert_eq!(&all_bits, b, "pool_size={pool_size}"),
        }
    }
}
