//! Sharded execution must never change a sampled bit.
//!
//! The worker pool splits batched denoise calls into contiguous row
//! shards; each row's float summation order stays inside the inner
//! model, so for any `pool_size` the ASD engine, the Picard sampler and
//! the lockstep batched sampler must reproduce the `pool_size = 1`
//! outputs exactly (same Philox streams, same bits) — together with all
//! accept/reject bookkeeping.

use std::sync::Arc;

use asd::asd::{AsdConfig, AsdEngine};
use asd::coordinator::{Coordinator, Request, SamplerSpec, ServerConfig};
use asd::ddpm::{BatchedSequentialSampler, NoiseStreams, SequentialSampler};
use asd::model::{DenoiseModel, Gmm, GmmDdpmOracle};
use asd::picard::{PicardConfig, PicardSampler};
use asd::runtime::pool::PoolConfig;
use asd::schedule::DdpmSchedule;

const POOL_SIZES: [usize; 3] = [1, 2, 8];

fn heavy_oracle(d: usize, components: usize, k: usize)
                -> Arc<dyn DenoiseModel> {
    GmmDdpmOracle::new(Gmm::random(d, components, 1.5, 3), k, false)
}

fn bits(v: &[f64]) -> Vec<u64> {
    asd::math::vec_ops::to_bits_vec(v)
}

#[test]
fn asd_bit_identical_across_pool_sizes() {
    let model = heavy_oracle(16, 12, 80);
    let mut reference: Option<(Vec<u64>, usize, usize, usize)> = None;
    for pool_size in POOL_SIZES {
        let mut engine = AsdEngine::new(
            model.clone(),
            AsdConfig {
                theta: 8,
                pool: PoolConfig { pool_size, shard_min: 1 },
                ..Default::default()
            });
        let mut all_bits = Vec::new();
        let mut rounds = 0usize;
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        for seed in 0..6u64 {
            let out = engine.sample(seed).unwrap();
            all_bits.extend(bits(&out.y0));
            rounds += out.stats.parallel_rounds;
            accepted += out.stats.accepted;
            rejected += out.stats.rejected;
            // bookkeeping invariants hold under sharding too
            assert_eq!(out.stats.round_shards.len(),
                       out.stats.parallel_rounds);
            assert_eq!(out.stats.round_latency_s.len(),
                       out.stats.parallel_rounds);
        }
        match &reference {
            None => reference = Some((all_bits, rounds, accepted, rejected)),
            Some((b, r, a, j)) => {
                assert_eq!(&all_bits, b,
                           "pool_size={pool_size} changed output bits");
                assert_eq!(rounds, *r, "pool_size={pool_size} rounds");
                assert_eq!(accepted, *a, "pool_size={pool_size} accepts");
                assert_eq!(rejected, *j, "pool_size={pool_size} rejects");
            }
        }
    }
}

#[test]
fn asd_theta_infinity_bit_identical_across_pool_sizes() {
    // ASD-inf produces the largest verify batches — the heaviest
    // sharding pattern
    let model = heavy_oracle(8, 6, 100);
    let mut reference: Option<Vec<u64>> = None;
    for pool_size in POOL_SIZES {
        let mut engine = AsdEngine::new(
            model.clone(),
            AsdConfig {
                theta: 0,
                pool: PoolConfig { pool_size, shard_min: 2 },
                ..Default::default()
            });
        let mut all_bits = Vec::new();
        for seed in 20..24u64 {
            all_bits.extend(bits(&engine.sample(seed).unwrap().y0));
        }
        match &reference {
            None => reference = Some(all_bits),
            Some(b) => assert_eq!(&all_bits, b, "pool_size={pool_size}"),
        }
    }
}

#[test]
fn picard_bit_identical_across_pool_sizes() {
    let model = heavy_oracle(16, 12, 60);
    let mut reference: Option<(Vec<u64>, usize)> = None;
    for pool_size in POOL_SIZES {
        let sampler = PicardSampler::new(
            model.clone(),
            PicardConfig {
                window: 8,
                tol: 1e-8,
                max_sweeps: 400,
                pool: PoolConfig { pool_size, shard_min: 1 },
            });
        let mut all_bits = Vec::new();
        let mut rounds = 0usize;
        for seed in 0..4u64 {
            let noise = NoiseStreams::draw(seed, 0, 60, 16);
            let (y0, st) = sampler.sample_with_noise(&noise, &[]).unwrap();
            all_bits.extend(bits(&y0));
            rounds += st.parallel_rounds;
        }
        match &reference {
            None => reference = Some((all_bits, rounds)),
            Some((b, r)) => {
                assert_eq!(&all_bits, b,
                           "pool_size={pool_size} changed Picard bits");
                assert_eq!(rounds, *r, "pool_size={pool_size} rounds");
            }
        }
    }
}

#[test]
fn batched_sequential_bit_identical_across_pool_sizes() {
    let model = heavy_oracle(16, 12, 40);
    // odd chain count on purpose: uneven shards
    let seeds: Vec<u64> = (0..7).collect();
    let mut reference: Option<Vec<u64>> = None;
    for pool_size in POOL_SIZES {
        let sampler = BatchedSequentialSampler::with_pool(
            model.clone(), PoolConfig { pool_size, shard_min: 1 });
        let (ys, st) = sampler.sample_batch(&seeds, &[]).unwrap();
        assert_eq!(st.model_calls, 40);
        let b = bits(&ys);
        match &reference {
            None => reference = Some(b),
            Some(want) => assert_eq!(&b, want, "pool_size={pool_size}"),
        }
    }
    // and the sharded lockstep result still matches per-request
    // sampling (tolerance as in the seed's batched_matches_individual)
    let per_request = SequentialSampler::new(model.clone());
    let pooled = BatchedSequentialSampler::with_pool(
        model, PoolConfig { pool_size: 8, shard_min: 1 });
    let (ys, _) = pooled.sample_batch(&seeds, &[]).unwrap();
    let d = 16;
    for (r, &seed) in seeds.iter().enumerate() {
        let (one, _) = per_request.sample(seed, &[]).unwrap();
        for i in 0..d {
            assert!((one[i] - ys[r * d + i]).abs() < 1e-9,
                    "row {r} dim {i}");
        }
    }
}

/// Steal-schedule leg: a mixed ASD + Picard + sequential burst served
/// through the full coordinator (two variants, two workers, fused
/// lanes, round tasks on the work-stealing pool) must return
/// bit-identical samples per request across row-shard pool sizes 1/2/8
/// AND across repeated runs — every repetition samples a different
/// steal/fusion/admission schedule, none of which may touch a bit.
#[test]
fn coordinator_burst_bit_identical_across_pool_sizes_and_schedules() {
    let model_a: Arc<dyn DenoiseModel> =
        GmmDdpmOracle::new(Gmm::random(8, 6, 1.5, 41), 50, false);
    let model_b: Arc<dyn DenoiseModel> =
        GmmDdpmOracle::new(Gmm::circle_2d(), 50, false);
    let run = |pool_size: usize| -> Vec<Vec<u64>> {
        let c = Coordinator::new(ServerConfig {
            workers: 2,
            max_batch: 8,
            enable_batching: true,
            pool: PoolConfig { pool_size, shard_min: 1 },
            ..Default::default()
        }).unwrap();
        c.register_model("a", model_a.clone());
        c.register_model("b", model_b.clone());
        let rxs: Vec<_> = (0..12u64)
            .map(|i| {
                let sampler = match i % 3 {
                    0 => SamplerSpec::Sequential,
                    1 => SamplerSpec::Asd(8),
                    _ => SamplerSpec::Picard(8, 1e-8),
                };
                let variant = if i % 2 == 0 { "a" } else { "b" };
                c.submit(Request {
                    id: 0,
                    variant: variant.into(),
                    sampler,
                    seed: 300 + i,
                    cond: vec![],
                    deadline: None,
                }).1
            })
            .collect();
        let out: Vec<Vec<u64>> = rxs.into_iter()
            .map(|rx| {
                let r = rx.recv().unwrap();
                assert!(r.error.is_none(), "{:?}", r.error);
                bits(&r.sample)
            })
            .collect();
        c.shutdown();
        out
    };
    let reference = run(1);
    for pool_size in POOL_SIZES {
        for rep in 0..3 {
            let got = run(pool_size);
            assert_eq!(got, reference,
                       "pool_size={pool_size} rep={rep} changed bits");
        }
    }
}

/// A denoiser that sleeps per round — a controlled straggler lane.
struct SleepyModel {
    sched: DdpmSchedule,
    delay: std::time::Duration,
}

impl DenoiseModel for SleepyModel {
    fn dim(&self) -> usize {
        1
    }
    fn cond_dim(&self) -> usize {
        0
    }
    fn k_steps(&self) -> usize {
        self.sched.k_steps
    }
    fn schedule(&self) -> &DdpmSchedule {
        &self.sched
    }
    fn denoise_batch(&self, _ys: &[f64], _ts: &[f64], _cond: &[f64],
                     n: usize, out: &mut [f64]) -> anyhow::Result<()> {
        std::thread::sleep(self.delay);
        out[..n].fill(0.0);
        Ok(())
    }
}

/// End-to-end proof the tick barrier is gone: with ONE coordinator
/// worker holding a straggler lane and a fast lane, the fast lane must
/// drain in a small fraction of the straggler's round window (the old
/// tick-synchronous driver stretched the fast lane to ~the straggler's
/// window, one barriered round at a time). Runs for any
/// ASD_POOL_THREADS — at one pool thread the driver itself executes
/// round tasks while it waits.
#[test]
fn single_worker_two_lane_burst_overlaps_without_barrier() {
    let c = Coordinator::new(ServerConfig {
        workers: 1,
        max_batch: 8,
        enable_batching: true,
        ..Default::default()
    }).unwrap();
    c.register_model("straggler", Arc::new(SleepyModel {
        sched: DdpmSchedule::new(30),
        delay: std::time::Duration::from_millis(4),
    }));
    c.register_model("fast", GmmDdpmOracle::new(Gmm::circle_2d(), 25,
                                                false));
    let mk = |variant: &str, seed| Request {
        id: 0,
        variant: variant.into(),
        sampler: SamplerSpec::Sequential,
        seed,
        cond: vec![],
        deadline: None,
    };
    let (_, rx_slow) = c.submit(mk("straggler", 1));
    let (_, rx_fast) = c.submit(mk("fast", 2));
    assert!(rx_fast.recv().unwrap().error.is_none());
    assert!(rx_slow.recv().unwrap().error.is_none());
    let m = c.metrics();
    let slow = m.lane("straggler").expect("straggler lane");
    let fast = m.lane("fast").expect("fast lane");
    assert!(slow.overlaps(fast), "lanes ran back to back");
    let slow_window = slow.last_round_ms - slow.first_round_ms;
    let fast_window = fast.last_round_ms - fast.first_round_ms;
    assert!(slow_window >= 50.0,
            "straggler finished implausibly fast: {slow_window:.2}ms");
    assert!(fast_window < slow_window * 0.5,
            "fast lane was gated by the straggler (tick barrier): \
             fast {fast_window:.2}ms vs slow {slow_window:.2}ms");
    assert!(m.pool.rounds > 0, "rounds did not flow through the pool");
    c.shutdown();
}

/// Reproducible-given-config tier, asserted end to end: a native MLP
/// whose GEMMs run on the load-resolved ISA (whatever this host — or
/// an `ASD_GEMM_ISA` override — picked) must produce bit-identical
/// samples across pool sizes 1/2/8 AND across repeated runs (each rep
/// samples a different steal schedule). The kernel config is frozen
/// per model, so the only thing sharding may change is wall-clock.
/// Multi-row rounds route through the compiled tile graph here (the
/// zero-barrier path), so the reps also sample graph ready-queue
/// orders — which likewise may not move a bit.
#[test]
fn native_mlp_bit_identical_across_pool_sizes_for_fixed_isa() {
    use asd::model::{NativeMlp, VariantInfo};
    let info = VariantInfo::toy("det", 3, 0, 24, 2, 40);
    let flat: Vec<f32> = (0..info.weights_len())
        .map(|i| ((i * 37 % 101) as f32 / 101.0) - 0.5)
        .collect();
    let mlp = NativeMlp::from_flat(&info, &flat).unwrap();
    let isa = mlp.isa();
    let model: Arc<dyn DenoiseModel> = mlp;
    let mut reference: Option<Vec<u64>> = None;
    for pool_size in POOL_SIZES {
        for rep in 0..2 {
            let mut engine = AsdEngine::new(
                model.clone(),
                AsdConfig {
                    theta: 8,
                    pool: PoolConfig { pool_size, shard_min: 1 },
                    ..Default::default()
                });
            let mut all_bits = Vec::new();
            for seed in 0..4u64 {
                all_bits.extend(bits(&engine.sample(seed).unwrap().y0));
            }
            match &reference {
                None => reference = Some(all_bits),
                Some(b) => assert_eq!(
                    &all_bits, b,
                    "pool_size={pool_size} rep={rep} changed native-MLP \
                     bits on isa={isa}"),
            }
        }
    }
}

#[test]
fn conditional_asd_bit_identical_across_pool_sizes() {
    let model: Arc<dyn DenoiseModel> =
        GmmDdpmOracle::new(Gmm::circle_2d(), 60, true);
    let mut cond = vec![0.0; 8];
    cond[5] = 1.0;
    let mut reference: Option<Vec<u64>> = None;
    for pool_size in POOL_SIZES {
        let mut engine = AsdEngine::new(
            model.clone(),
            AsdConfig {
                theta: 8,
                pool: PoolConfig { pool_size, shard_min: 1 },
                ..Default::default()
            });
        let mut all_bits = Vec::new();
        for seed in 0..4u64 {
            all_bits.extend(bits(&engine.sample_cond(seed, &cond)
                                 .unwrap().y0));
        }
        match &reference {
            None => reference = Some(all_bits),
            Some(b) => assert_eq!(&all_bits, b, "pool_size={pool_size}"),
        }
    }
}
