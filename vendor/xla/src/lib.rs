//! Offline stub of the `xla` PJRT bindings.
//!
//! The real bindings need the XLA/PJRT shared library, which is not
//! present in this build environment. This stub keeps the whole
//! workspace compiling with the exact call-site API the runtime layer
//! uses (`PjRtClient::cpu`, `compile`, `buffer_from_host_buffer`,
//! `execute_b`, literal decomposition), while `PjRtClient::cpu()`
//! reports the backend as unavailable. `DeviceHandle::spawn` surfaces
//! that as a clean error and every PJRT-dependent test skips; the
//! pure-rust engine, samplers, worker pool and analytic oracles never
//! touch this crate at runtime.
//!
//! To enable the HLO path, replace the `xla` entry in the workspace
//! `Cargo.toml` with the real bindings — no rust/src changes needed.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "XLA/PJRT backend unavailable (vendored stub build); point the \
         workspace `xla` dependency at the real bindings to enable the \
         HLO path"
            .to_string(),
    ))
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self, _data: &[T], _dims: &[usize], _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer])
                     -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = match PjRtClient::cpu() {
            Ok(_) => panic!("stub must not produce a client"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("unavailable"));
    }
}
