//! Minimal, API-compatible stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline (no crates.io), so the subset
//! of `anyhow` this workspace actually uses is vendored here: [`Error`],
//! [`Result`], the [`anyhow!`], [`bail!`] and [`ensure!`] macros, and
//! the [`Context`] extension trait on `Result` and `Option`.
//!
//! Semantics mirror upstream where it matters to callers:
//! * `Display` prints the outermost message only;
//! * `{:#}` (alternate) prints the whole context chain `outer: ...: root`;
//! * `Debug` (what `unwrap()` shows) prints the chain with a
//!   `Caused by:` trailer;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::fmt;

/// A dynamic error carrying a chain of context messages.
/// `chain[0]` is the outermost context, `chain.last()` the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (upstream: `Error::context`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((outer, rest)) => {
                write!(f, "{outer}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, c) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {c}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

// Mirrors upstream: no overlap with the reflexive `From<Error> for Error`
// because `Error` itself deliberately does NOT implement
// `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Conversion helper behind [`Context`] (upstream: `ext::StdError`).
/// Blanket impl for std errors plus a concrete impl for [`Error`]; these
/// are disjoint because `Error` is not a `std::error::Error`.
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Attach context to errors (and to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T, Error>;
}

impl<T, E: IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().context(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().context(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T, Error> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading weights".to_string())
            .unwrap_err();
        assert_eq!(e.to_string(), "reading weights");
        assert_eq!(format!("{e:#}"), "reading weights: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u8> = None;
        let e = none.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");

        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(7).unwrap_err().to_string().contains("unlucky 7"));
        let e = anyhow!("plain {}", 5);
        assert_eq!(e.to_string(), "plain 5");
    }

    #[test]
    fn context_on_anyhow_result_stacks() {
        let base: Result<()> = Err(anyhow!("root"));
        let e = base.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
        assert_eq!(e.root_cause(), "root");
    }
}
