"""L2 model: Pallas path == pure-jnp path; weight layout; training smoke."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (ModelConfig, denoise_pallas, denoise_ref,
                           flatten_params, init_params, layer_dims,
                           time_embedding)


def _cfg(d=4, cond=3, hidden=16, layers=2, k=50):
    return ModelConfig(d=d, cond_dim=cond, hidden=hidden, layers=layers,
                       k_steps=k)


@settings(max_examples=8, deadline=None)
@given(b=st.sampled_from([1, 2, 8]), d=st.sampled_from([2, 16]),
       cond=st.sampled_from([0, 10]), layers=st.sampled_from([1, 3]),
       seed=st.integers(0, 2**10))
def test_pallas_forward_matches_ref(b, d, cond, layers, seed):
    cfg = _cfg(d=d, cond=cond, hidden=32, layers=layers)
    params = [(jnp.asarray(w), jnp.asarray(bb))
              for w, bb in init_params(cfg, seed)]
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    t = jnp.asarray(rng.integers(1, cfg.k_steps + 1, b), jnp.float32)
    c = jnp.asarray(rng.standard_normal((b, cond)), jnp.float32)
    out_p = denoise_pallas(params, y, t, c, cfg)
    out_r = denoise_ref(params, y, t, c, cfg)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-4, atol=1e-5)


def test_time_embedding_distinguishes_steps():
    k = 100
    e = np.asarray(time_embedding(jnp.asarray([1.0, 2.0, 50.0, 100.0]), k))
    assert e.shape == (4, 32)
    # distinct steps get distinct embeddings
    for i in range(4):
        for j in range(i + 1, 4):
            assert np.linalg.norm(e[i] - e[j]) > 1e-3
    assert np.all(np.abs(e) <= 1.0 + 1e-6)


def test_flatten_params_layout():
    cfg = _cfg(d=3, cond=0, hidden=5, layers=2)
    params = init_params(cfg, 0)
    flat = flatten_params(params)
    dims = layer_dims(cfg)
    expect = sum(a * b + b for a, b in dims)
    assert flat.shape == (expect,)
    # first weight matrix occupies the head of the buffer, row-major
    w0 = params[0][0]
    np.testing.assert_array_equal(flat[: w0.size], w0.ravel())


def test_layer_dims():
    cfg = _cfg(d=4, cond=3, hidden=16, layers=2)
    assert layer_dims(cfg) == [(4 + 32 + 3, 16), (16, 16), (16, 4)]


def test_training_reduces_loss():
    from compile.train import train_variant
    from compile.variants import _v

    v = _v("tiny", d=2, cond_dim=0, hidden=32, layers=2, k=20,
           target="gmm2d", train_steps=300, batch_size=128, seed=5)
    params, final_loss = train_variant(v)
    # initial loss for this target is ~ E||x0||^2 ~ 2.3; training should
    # cut it below the unconditional-mean floor averaged over noise levels
    assert final_loss < 2.2
