"""L1 Pallas kernels vs pure-jnp oracles (the core correctness signal).

hypothesis sweeps shapes; fixed-seed numpy draws the values (kernels are
deterministic functions of their inputs — all randomness is an input).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (ACT_NONE, ACT_SILU, fused_linear, grs_verify,
                             speculate)
from compile.kernels.ref import (fused_linear_ref, grs_verify_ref,
                                 speculate_prefix_ref, speculate_ref)

_SETTINGS = dict(max_examples=12, deadline=None)


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(b=st.sampled_from([1, 2, 3, 8, 32]),
       n_in=st.sampled_from([2, 7, 64, 130]),
       n_out=st.sampled_from([1, 16, 128]),
       act=st.sampled_from([ACT_NONE, ACT_SILU]),
       seed=st.integers(0, 2**16))
def test_fused_linear_matches_ref(b, n_in, n_out, act, seed):
    rng = _rng(seed)
    x = rng.standard_normal((b, n_in)).astype(np.float32)
    w = rng.standard_normal((n_in, n_out)).astype(np.float32)
    bias = rng.standard_normal(n_out).astype(np.float32)
    got = fused_linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), act)
    want = fused_linear_ref(jnp.asarray(x), jnp.asarray(w),
                            jnp.asarray(bias), act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused_linear_silu_values():
    # silu(0) = 0; silu(large) ~ identity
    x = jnp.asarray([[0.0, 100.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros(2, jnp.float32)
    out = np.asarray(fused_linear(x, w, b, ACT_SILU))
    assert abs(out[0, 0]) < 1e-7
    np.testing.assert_allclose(out[0, 1], 100.0, rtol=1e-6)


def test_fused_linear_shape_mismatch_raises():
    with pytest.raises(AssertionError):
        fused_linear(jnp.zeros((2, 3)), jnp.zeros((4, 5)), jnp.zeros(5))


# ---------------------------------------------------------------------------
# speculate
# ---------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(t=st.sampled_from([1, 2, 5, 32]),
       d=st.sampled_from([1, 2, 16, 112]),
       seed=st.integers(0, 2**16))
def test_speculate_matches_scan_ref(t, d, seed):
    rng = _rng(seed)
    y_a = rng.standard_normal(d).astype(np.float32)
    x0a = rng.standard_normal(d).astype(np.float32)
    c1 = rng.uniform(0, 0.2, t).astype(np.float32)
    c2 = rng.uniform(0.8, 1.0, t).astype(np.float32)
    sigma = rng.uniform(0, 0.1, t).astype(np.float32)
    xi = rng.standard_normal((t, d)).astype(np.float32)
    args = tuple(jnp.asarray(a) for a in (y_a, x0a, c1, c2, sigma, xi))
    m_hat, y_hat = speculate(*args)
    m_ref, y_ref = speculate_ref(*args)
    np.testing.assert_allclose(np.asarray(m_hat), np.asarray(m_ref),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(y_hat), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-6)


@settings(**_SETTINGS)
@given(t=st.sampled_from([1, 3, 32]), d=st.sampled_from([2, 16]),
       seed=st.integers(0, 2**16))
def test_prefix_scan_equals_sequential_scan(t, d, seed):
    """The paper's O~(1) associative-scan formulation == the recurrence."""
    rng = _rng(seed)
    args = tuple(jnp.asarray(a) for a in (
        rng.standard_normal(d).astype(np.float32),
        rng.standard_normal(d).astype(np.float32),
        rng.uniform(0, 0.2, t).astype(np.float32),
        rng.uniform(0.8, 1.0, t).astype(np.float32),
        rng.uniform(0, 0.1, t).astype(np.float32),
        rng.standard_normal((t, d)).astype(np.float32)))
    m_seq, y_seq = speculate_ref(*args)
    m_pre, y_pre = speculate_prefix_ref(*args)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_pre),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(m_seq), np.asarray(m_pre),
                               rtol=1e-4, atol=1e-5)


def test_speculate_first_step_mean():
    """Chain position 0: m_hat = c1*x0a + c2*y_a exactly."""
    y_a = jnp.asarray([1.0, -2.0], jnp.float32)
    x0a = jnp.asarray([0.5, 0.5], jnp.float32)
    one = jnp.asarray([0.1], jnp.float32)
    m_hat, _ = speculate(y_a, x0a, one, jnp.asarray([0.9], jnp.float32),
                         jnp.asarray([0.0], jnp.float32),
                         jnp.zeros((1, 2), jnp.float32))
    np.testing.assert_allclose(np.asarray(m_hat)[0],
                               0.1 * np.asarray(x0a) + 0.9 * np.asarray(y_a),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# grs_verify
# ---------------------------------------------------------------------------

@settings(**_SETTINGS)
@given(t=st.sampled_from([1, 4, 32]), d=st.sampled_from([1, 2, 16, 64]),
       seed=st.integers(0, 2**16))
def test_grs_matches_ref(t, d, seed):
    rng = _rng(seed)
    u = rng.uniform(0, 1, t).astype(np.float32)
    xi = rng.standard_normal((t, d)).astype(np.float32)
    m_hat = rng.standard_normal((t, d)).astype(np.float32)
    m = m_hat + 0.3 * rng.standard_normal((t, d)).astype(np.float32)
    sigma = rng.uniform(0.01, 1.0, t).astype(np.float32)
    args = tuple(jnp.asarray(a) for a in (u, xi, m_hat, m, sigma))
    z, acc = grs_verify(*args)
    z_ref, acc_ref = grs_verify_ref(*args)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(acc_ref))


def test_grs_equal_means_always_accepts():
    """Lemma 13 mechanism: v = 0 => accept regardless of u."""
    t, d = 8, 4
    rng = _rng(1)
    m = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    xi = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    u = jnp.asarray(np.linspace(0.0, 1.0, t), jnp.float32)
    sigma = jnp.full((t,), 0.5, jnp.float32)
    z, acc = grs_verify(u, xi, m, m, sigma)
    assert np.all(np.asarray(acc) == 1.0)
    np.testing.assert_allclose(np.asarray(z),
                               np.asarray(m) + 0.5 * np.asarray(xi),
                               rtol=1e-6)


def test_grs_sigma_zero_dirac():
    u = jnp.asarray([0.5, 0.5], jnp.float32)
    xi = jnp.asarray(_rng(2).standard_normal((2, 3)), jnp.float32)
    m = jnp.asarray(_rng(3).standard_normal((2, 3)), jnp.float32)
    m_hat = m.at[1].add(1.0)  # row 0 equal, row 1 different
    sigma = jnp.zeros((2,), jnp.float32)
    z, acc = grs_verify(u, xi, m_hat, m, sigma)
    assert np.asarray(acc).tolist() == [1.0, 0.0]
    np.testing.assert_allclose(np.asarray(z), np.asarray(m), rtol=1e-6)


def test_grs_reflection_preserves_norm():
    """Rejected branch: reflect(xi) has the same norm as xi."""
    rng = _rng(4)
    t, d = 16, 8
    u = jnp.ones((t,), jnp.float32)  # force rejection unless ratio >= 1
    xi = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    m = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    m_hat = m + 5.0  # large v => essentially always reject at u=1
    sigma = jnp.full((t,), 0.3, jnp.float32)
    z, acc = grs_verify(u, xi, m_hat, m, sigma)
    rej = np.asarray(acc) == 0.0
    assert rej.sum() >= t - 2
    refl = (np.asarray(z)[rej] - np.asarray(m)[rej]) / 0.3
    np.testing.assert_allclose(np.linalg.norm(refl, axis=1),
                               np.linalg.norm(np.asarray(xi)[rej], axis=1),
                               rtol=1e-4)


def test_grs_statistical_correctness():
    """Theorem 12: z ~ N(m, sigma^2 I) regardless of m_hat, and
    P[reject] ~= TV(N(m_hat, s^2), N(m, s^2)) = 2 Phi(||v||/2s) - 1."""
    from scipy_free_norm import normal_cdf  # local helper below

    rng = _rng(5)
    n, d, s = 20000, 3, 0.7
    m = np.zeros(d, np.float32)
    m_hat = np.asarray([0.5, -0.3, 0.2], np.float32)
    u = rng.uniform(0, 1, n).astype(np.float32)
    xi = rng.standard_normal((n, d)).astype(np.float32)
    z, acc = grs_verify(jnp.asarray(u), jnp.asarray(xi),
                        jnp.broadcast_to(m_hat, (n, d)),
                        jnp.broadcast_to(m, (n, d)),
                        jnp.full((n,), s, jnp.float32))
    z = np.asarray(z)
    # marginal moments of z
    np.testing.assert_allclose(z.mean(0), m, atol=4 * s / np.sqrt(n) * 3)
    np.testing.assert_allclose(z.std(0), s, rtol=0.05)
    # rejection probability == TV distance
    v_norm = float(np.linalg.norm(m_hat - m))
    tv = 2.0 * normal_cdf(v_norm / (2.0 * s)) - 1.0
    p_rej = 1.0 - float(np.asarray(acc).mean())
    assert abs(p_rej - tv) < 0.015, (p_rej, tv)
