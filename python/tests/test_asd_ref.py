"""ASD reference implementation vs sequential DDPM (Theorems 1, 3, 4).

The model here is the *analytic* GMM posterior mean (no NN error), so the
tests exercise exactly the algorithmic claims:

* Thm 3 — ASD output is distributed identically to sequential DDPM
  (two-sample moment tests over many seeds).
* Lemma 13 — the first speculated step of every window is accepted.
* Thm 4 flavour — ASD's parallel rounds shrink as theta grows; ASD-inf
  beats sequential by a clear margin.
* Thm 1 — exchangeability of SL increments (direct simulation).
"""

import numpy as np
import pytest

from compile.asd_ref import asd, sequential_ddpm
from compile.schedule import make_schedule
from compile import targets


def gmm_x0_posterior(means, sigmas, weights):
    """E[x0 | y_i] for a GMM target under the DDPM forward process:
    y_i = sqrt(abar_i) x0 + sqrt(1-abar_i) eps."""

    def model(y, i, *, abar):
        a = abar[i - 1]
        sa = np.sqrt(a)
        var = a * sigmas ** 2 + (1.0 - a)            # per component
        diff = y[None, :] - sa * means               # (C, d)
        logw = (np.log(weights) - 0.5 * np.sum(diff ** 2, -1) / var
                - 0.5 * len(y) * np.log(var))
        logw -= logw.max()
        r = np.exp(logw)
        r /= r.sum()
        # E[x0 | y, c] = (sa * sigma_c^2 * y/..) standard conditioning:
        gain = sa * sigmas ** 2 / var                # (C,)
        cond_mean = means + gain[:, None] * (diff)   # means + gain (y - sa mu)
        return r @ cond_mean

    return model


@pytest.fixture(scope="module")
def gmm_setup():
    means, sigmas, weights = targets.gmm2d_params()
    k = 60
    sched = make_schedule(k)
    raw = gmm_x0_posterior(means, sigmas, weights)

    def model(y, i):
        return raw(y, i, abar=sched["abar"])

    return model, k, sched


def _sample_many(sampler, n, seed0, d=2, k=60):
    out = np.empty((n, d))
    for s in range(n):
        rng = np.random.default_rng(seed0 + s)
        y_k = rng.standard_normal(d)
        xi = rng.standard_normal((k, d))
        u = rng.uniform(0, 1, k)
        out[s] = sampler(y_k, xi, u)
    return out


def test_asd_matches_sequential_distribution(gmm_setup):
    model, k, sched = gmm_setup
    n = 400

    seq = _sample_many(
        lambda y, xi, u: sequential_ddpm(model, y, k, sched, xi),
        n, seed0=100, k=k)
    spec = _sample_many(
        lambda y, xi, u: asd(model, None, y, k, sched, u, xi, theta=8)[0],
        n, seed0=100, k=k)

    # same target: compare radial distribution + first two moments
    r_seq = np.linalg.norm(seq, axis=1)
    r_asd = np.linalg.norm(spec, axis=1)
    assert abs(r_seq.mean() - r_asd.mean()) < 0.08
    assert abs(r_seq.std() - r_asd.std()) < 0.08
    assert np.all(np.abs(seq.mean(0) - spec.mean(0)) < 0.15)


def test_asd_exactness_vs_target(gmm_setup):
    """ASD samples should land on the GMM modes (radius ~1.5)."""
    model, k, sched = gmm_setup
    spec = _sample_many(
        lambda y, xi, u: asd(model, None, y, k, sched, u, xi, theta=0)[0],
        200, seed0=999, k=k)
    r = np.linalg.norm(spec, axis=1)
    assert abs(r.mean() - targets.GMM2D_RADIUS) < 0.1
    assert r.std() < 0.3


def test_lemma13_first_speculation_always_accepted(gmm_setup):
    model, k, sched = gmm_setup
    rng = np.random.default_rng(0)
    for trial in range(5):
        y_k = rng.standard_normal(2)
        xi = rng.standard_normal((k, 2))
        u = rng.uniform(0, 1, k)
        _, stats = asd(model, None, y_k, k, sched, u, xi, theta=4)
        # every iteration advances by >= 1 accepted step => iterations <= K
        # and, with theta >= 2, rejections only happen at positions >= 1:
        assert stats.accepted >= stats.iterations
        assert stats.accepted + stats.rejected == k


def test_asd_rounds_decrease_with_theta(gmm_setup):
    model, k, sched = gmm_setup
    rng = np.random.default_rng(42)
    rounds = {}
    for theta in (1, 4, 16, 0):  # 0 = infinity
        tot = 0
        for trial in range(4):
            seed_rng = np.random.default_rng(1000 + trial)
            y_k = seed_rng.standard_normal(2)
            xi = seed_rng.standard_normal((k, 2))
            u = seed_rng.uniform(0, 1, k)
            _, stats = asd(model, None, y_k, k, sched, u, xi, theta=theta)
            tot += stats.parallel_rounds
        rounds[theta] = tot / 4
    assert rounds[4] < rounds[1]
    assert rounds[16] <= rounds[4] + 1
    assert rounds[0] <= rounds[16] + 1
    # ASD-inf must beat sequential's K rounds decisively
    assert rounds[0] < 0.75 * k


def test_asd_theta1_equals_half_speed(gmm_setup):
    """theta=1: every window is the always-accepted step => exactly K
    iterations; with eval_tail chaining the proposal is free, so rounds
    ~= K (not 2K)."""
    model, k, sched = gmm_setup
    rng = np.random.default_rng(3)
    y_k = rng.standard_normal(2)
    xi = rng.standard_normal((k, 2))
    u = rng.uniform(0, 1, k)
    _, stats = asd(model, None, y_k, k, sched, u, xi, theta=1)
    assert stats.iterations == k
    assert stats.rejected == 0


def test_exchangeability_of_sl_increments():
    """Thm 1 by direct simulation: ybar_t = t x* + W_t; equal-eta
    increments are exchangeable => any permutation has the same joint
    law. Check pairwise product moments under a swap."""
    rng = np.random.default_rng(0)
    n, m, eta = 40000, 4, 0.25
    x_star = rng.choice([-1.0, 1.0], size=n)  # Rademacher target
    # increments: Delta_i = eta x* + (W_{t+eta} - W_t)
    deltas = eta * x_star[:, None] + np.sqrt(eta) * rng.standard_normal(
        (n, m))
    # moments invariant under permutation of the m increments
    m12 = (deltas[:, 0] * deltas[:, 1]).mean()
    m23 = (deltas[:, 1] * deltas[:, 2]).mean()
    m03 = (deltas[:, 0] * deltas[:, 3]).mean()
    tol = 4.0 / np.sqrt(n)
    assert abs(m12 - m23) < tol
    assert abs(m12 - m03) < tol
    # and the marginal laws match
    assert abs(deltas[:, 0].mean() - deltas[:, 3].mean()) < tol
    assert abs(deltas[:, 0].std() - deltas[:, 2].std()) < tol
