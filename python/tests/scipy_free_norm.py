"""Standard normal CDF via erf (scipy is not available offline)."""

import math


def normal_cdf(x: float) -> float:
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))
