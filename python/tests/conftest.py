import os
import sys

# Make `compile` importable when pytest runs from the repo root or python/.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
