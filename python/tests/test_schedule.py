"""DDPM schedule identities + SL reparametrization round-trips."""

import numpy as np
import pytest

from compile.schedule import (ddpm_time_of_sl, make_betas, make_schedule,
                              sl_time_of_ddpm)


@pytest.mark.parametrize("k", [50, 100, 1000])
def test_posterior_mean_identity(k):
    """c1_i + c2_i sqrt(abar_i) == sqrt(abar_{i-1}): a noiseless iterate
    with a perfect model denoises onto the noiseless trajectory."""
    s = make_schedule(k)
    lhs = s["c1"] + s["c2"] * np.sqrt(s["abar"])
    np.testing.assert_allclose(lhs, np.sqrt(s["abar_prev"]), rtol=1e-10)


@pytest.mark.parametrize("k", [50, 100, 1000])
def test_posterior_variance_identity(k):
    """c2_i^2 (1-abar_i) + sigma_i^2 == 1 - abar_{i-1}: the forward
    marginal variance is preserved by the reverse update."""
    s = make_schedule(k)
    lhs = s["c2"] ** 2 * (1.0 - s["abar"]) + s["sigma"] ** 2
    np.testing.assert_allclose(lhs, 1.0 - s["abar_prev"], rtol=1e-10)


@pytest.mark.parametrize("k", [100, 1000])
def test_schedule_shapes_and_bounds(k):
    s = make_schedule(k)
    for key in ("betas", "alphas", "abar", "c1", "c2", "sigma"):
        assert s[key].shape == (k,)
    assert s["sigma"][0] == 0.0           # final reverse step is a Dirac
    assert np.all(s["sigma"][1:] > 0.0)
    assert np.all(np.diff(s["abar"]) < 0)  # strictly decreasing
    assert s["abar"][-1] < 5e-5            # fully noised at i = K


def test_beta_rescaling_keeps_total_noise():
    """abar_K is (nearly) K-independent thanks to the 1000/K rescale."""
    a100 = make_schedule(100)["abar"][-1]
    a1000 = make_schedule(1000)["abar"][-1]
    assert abs(np.log(a100) - np.log(a1000)) < 2.0


def test_sl_time_roundtrip():
    s = np.linspace(0.01, 5.0, 50)
    np.testing.assert_allclose(ddpm_time_of_sl(sl_time_of_ddpm(s)), s,
                               rtol=1e-9)


def test_betas_positive_and_below_one():
    for k in (50, 100, 1000):
        b = make_betas(k)
        assert np.all(b > 0) and np.all(b < 1)
