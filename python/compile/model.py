"""L2 JAX model: x0-predicting MLP denoiser.

Two mathematically identical forward paths:

* ``denoise_pallas`` — composes the L1 ``fused_linear`` Pallas kernel;
  this is what ``aot.py`` lowers into the HLO artifacts the Rust runtime
  executes (the request-path function).
* ``denoise_ref`` — pure jnp; used by the (CPU, jit-compiled) training
  loop where interpret-mode Pallas would be needlessly slow, and as the
  pytest oracle that pins the two paths together.

Architecture: concat[y, sinusoidal_temb(i), cond] -> Linear+SiLU ->
(L-1) x residual(Linear+SiLU) -> Linear -> x0hat. Weights are a flat list
[(W, b), ...]; `flatten_params` defines the byte layout shared with
rust/src/model/mlp.rs (the rust-native parity oracle).
"""

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ACT_NONE, ACT_SILU, fused_linear
from .kernels.ref import fused_linear_ref

TEMB_DIM = 32


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    d: int            # data dimension (flattened)
    cond_dim: int     # conditioning dimension (0 = unconditional)
    hidden: int
    layers: int       # number of hidden layers (>= 1)
    k_steps: int      # diffusion steps K

    @property
    def in_dim(self) -> int:
        return self.d + TEMB_DIM + self.cond_dim


def time_embedding(t: jax.Array, k_steps: int, dim: int = TEMB_DIM):
    """Sinusoidal embedding of the integer step index t in 1..K.

    t: (B,) float32 (step indices). Returns (B, dim).
    """
    half = dim // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = (t[:, None] / k_steps) * 1000.0 * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_params(cfg: ModelConfig, seed: int) -> List[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    dims = [cfg.in_dim] + [cfg.hidden] * cfg.layers + [cfg.d]
    params = []
    for n_in, n_out in zip(dims[:-1], dims[1:]):
        w = rng.standard_normal((n_in, n_out)) * np.sqrt(2.0 / n_in)
        b = np.zeros(n_out)
        params.append((w.astype(np.float32), b.astype(np.float32)))
    return params


def _forward(params, y, t, cond, cfg: ModelConfig, linear):
    """Shared forward skeleton; `linear(x, w, b, act)` is injected."""
    temb = time_embedding(t, cfg.k_steps)
    parts = [y, temb]
    if cfg.cond_dim > 0:
        parts.append(cond)
    h = jnp.concatenate(parts, axis=-1).astype(jnp.float32)
    w0, b0 = params[0]
    h = linear(h, w0, b0, ACT_SILU)
    for w, b in params[1:-1]:
        h = h + linear(h, w, b, ACT_SILU)  # residual hidden blocks
    w_out, b_out = params[-1]
    return linear(h, w_out, b_out, ACT_NONE)


def denoise_pallas(params, y, t, cond, cfg: ModelConfig):
    """(B,d), (B,), (B,cond_dim) -> x0hat (B,d) via Pallas kernels."""
    return _forward(params, y, t, cond, cfg, fused_linear)


def denoise_ref(params, y, t, cond, cfg: ModelConfig):
    """Pure-jnp twin of denoise_pallas (training + oracle)."""
    return _forward(params, y, t, cond, cfg, fused_linear_ref)


# ---------------------------------------------------------------------------
# Weight (de)serialization — layout shared with rust/src/model/mlp.rs
# ---------------------------------------------------------------------------

def flatten_params(params) -> np.ndarray:
    """Flat f32 buffer: for each layer, W row-major (n_in, n_out) then b."""
    chunks = []
    for w, b in params:
        chunks.append(np.asarray(w, dtype=np.float32).ravel())
        chunks.append(np.asarray(b, dtype=np.float32).ravel())
    return np.concatenate(chunks)


def layer_dims(cfg: ModelConfig) -> List[Tuple[int, int]]:
    dims = [cfg.in_dim] + [cfg.hidden] * cfg.layers + [cfg.d]
    return list(zip(dims[:-1], dims[1:]))
