"""Point-mass manipulation environments (Robomimic stand-ins).

DESIGN.md §7: deterministic kinematics shared verbatim with
rust/src/env/ — python generates expert demonstrations for behaviour
cloning; rust evaluates the trained diffusion policies (Table 3 / Fig 5).
Any change here MUST be mirrored in rust/src/env/point_mass.rs.

Model: n_arms point masses with 2-D position and a binary gripper.
Action per arm is 7-D ([dx, dy, grip, 4 unused] — matching the paper's
7-DoF OSC action space; unused dims carry expert noise and are modelled
by the policy but ignored by the dynamics). An episode is a sequence of
"legs":

  GRASP      — move gripper to the object and close: succeeds when the
               arm's grip is closed within `tol` of the object.
  VIA(x, y)  — pass within `tol` of a waypoint while carrying.
  PLACE(x,y) — release the object within `tol` of the target.

Success = all legs completed within `max_steps`. Tasks:

  square     1 arm,  grasp(.05) -> place(.3,.7; .06)            ~easy
  transport  2 arms, grasp(.05) -> place-handoff(.5,.5; .05) by arm0,
             grasp(.05) -> place(.85,.5; .07) by arm1           ~medium
  toolhang   1 arm,  grasp(.035) -> via(.5,.35) -> via(.55,.75)
             -> place(.62,.8), all tol .035                     ~hard
"""

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

DT = 0.05
ACTION_DIM_PER_ARM = 7
CHUNK = 16        # diffusion policy action-chunk length (paper: k=16)
EXEC_STEPS = 8    # receding horizon: execute 8, replan

LEG_GRASP = 0
LEG_VIA = 1
LEG_PLACE = 2


@dataclasses.dataclass(frozen=True)
class Leg:
    arm: int
    kind: int
    target: Optional[Tuple[float, float]]  # None for GRASP
    tol: float


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    name: str
    n_arms: int
    obj_box: Tuple[float, float, float, float]       # lox, loy, hix, hiy
    ee_start: List[Tuple[float, float, float, float]]  # per arm
    legs: List[Leg]
    max_steps: int
    expert_noise: float

    @property
    def action_dim(self) -> int:
        return ACTION_DIM_PER_ARM * self.n_arms

    @property
    def obs_dim(self) -> int:
        # ee(2/arm) + grip(1/arm) + obj(2) + carried onehot(n_arms+1)
        # + leg fraction(1) + current leg target(2)
        return 3 * self.n_arms + 2 + (self.n_arms + 1) + 1 + 2

    @property
    def chunk_dim(self) -> int:
        return CHUNK * self.action_dim


SQUARE = TaskSpec(
    name="square", n_arms=1,
    obj_box=(0.55, 0.15, 0.85, 0.45),
    ee_start=[(0.05, 0.05, 0.30, 0.30)],
    legs=[Leg(0, LEG_GRASP, None, 0.05),
          Leg(0, LEG_PLACE, (0.30, 0.70), 0.06)],
    max_steps=100, expert_noise=0.07,
)

TRANSPORT = TaskSpec(
    name="transport", n_arms=2,
    obj_box=(0.10, 0.40, 0.30, 0.60),
    ee_start=[(0.05, 0.05, 0.25, 0.25), (0.75, 0.75, 0.95, 0.95)],
    legs=[Leg(0, LEG_GRASP, None, 0.05),
          Leg(0, LEG_PLACE, (0.50, 0.50), 0.05),
          Leg(1, LEG_GRASP, None, 0.05),
          Leg(1, LEG_PLACE, (0.85, 0.50), 0.07)],
    max_steps=160, expert_noise=0.07,
)

TOOLHANG = TaskSpec(
    name="toolhang", n_arms=1,
    obj_box=(0.15, 0.10, 0.45, 0.30),
    ee_start=[(0.60, 0.60, 0.85, 0.85)],
    legs=[Leg(0, LEG_GRASP, None, 0.035),
          Leg(0, LEG_VIA, (0.50, 0.35), 0.035),
          Leg(0, LEG_VIA, (0.55, 0.75), 0.035),
          Leg(0, LEG_PLACE, (0.62, 0.80), 0.035)],
    max_steps=120, expert_noise=0.12,
)

TASKS = {t.name: t for t in (SQUARE, TRANSPORT, TOOLHANG)}


class PointMassEnv:
    """Deterministic kinematics; all randomness enters via reset(rng) and
    the actions. Mirrored by rust/src/env/point_mass.rs."""

    def __init__(self, spec: TaskSpec):
        self.spec = spec

    def reset(self, rng: np.random.Generator):
        s = self.spec
        self.ee = np.array([
            [rng.uniform(b[0], b[2]), rng.uniform(b[1], b[3])]
            for b in s.ee_start])
        self.grip = np.zeros(s.n_arms, dtype=bool)
        b = s.obj_box
        self.obj = np.array([rng.uniform(b[0], b[2]), rng.uniform(b[1], b[3])])
        self.carried = -1
        self.leg_idx = 0
        self.steps = 0
        self.failed = False
        return self.obs()

    # -- observation ------------------------------------------------------
    def obs(self) -> np.ndarray:
        s = self.spec
        carried_oh = np.zeros(s.n_arms + 1)
        carried_oh[self.carried + 1] = 1.0
        if self.leg_idx < len(s.legs):
            leg = s.legs[self.leg_idx]
            tgt = self.obj if leg.kind == LEG_GRASP else np.asarray(leg.target)
        else:
            tgt = self.obj
        return np.concatenate([
            self.ee.ravel(), self.grip.astype(np.float64),
            self.obj, carried_oh,
            [self.leg_idx / len(s.legs)], tgt])

    @property
    def done(self) -> bool:
        return (self.leg_idx >= len(self.spec.legs) or self.failed
                or self.steps >= self.spec.max_steps)

    @property
    def success(self) -> bool:
        return self.leg_idx >= len(self.spec.legs) and not self.failed

    # -- dynamics ---------------------------------------------------------
    def step(self, action: np.ndarray):
        s = self.spec
        assert action.shape == (s.action_dim,)
        self.steps += 1
        for a in range(s.n_arms):
            d = np.clip(action[7 * a: 7 * a + 2], -1.0, 1.0)
            self.ee[a] = self.ee[a] + DT * d
            self.grip[a] = action[7 * a + 2] > 0.0

        # dropping: carrier opened its grip
        if self.carried >= 0 and not self.grip[self.carried]:
            dropped_by = self.carried
            self.carried = -1
            # if the current leg required carrying, check it wasn't a
            # successful PLACE (handled below); VIA legs fail on drop
            leg = s.legs[self.leg_idx] if self.leg_idx < len(s.legs) else None
            if leg is not None and leg.kind == LEG_VIA and leg.arm == dropped_by:
                self.failed = True

        if self.carried >= 0:
            self.obj = self.ee[self.carried].copy()

        if self.leg_idx < len(s.legs):
            leg = s.legs[self.leg_idx]
            if leg.kind == LEG_GRASP:
                if (self.carried == -1 and self.grip[leg.arm]
                        and _dist(self.ee[leg.arm], self.obj) < leg.tol):
                    self.carried = leg.arm
                    self.leg_idx += 1
            elif leg.kind == LEG_VIA:
                if (self.carried == leg.arm
                        and _dist(self.ee[leg.arm], np.asarray(leg.target)) < leg.tol):
                    self.leg_idx += 1
            elif leg.kind == LEG_PLACE:
                if (self.carried == -1 and not self.grip[leg.arm]
                        and _dist(self.obj, np.asarray(leg.target)) < leg.tol):
                    self.leg_idx += 1
        return self.obs()


def _dist(a, b) -> float:
    return float(np.hypot(a[0] - b[0], a[1] - b[1]))


# ---------------------------------------------------------------------------
# Scripted expert (P-controller over the current leg) — demo generation
# ---------------------------------------------------------------------------

KP = 4.0
GRIP_CLOSE_FRAC = 0.9   # close/open the gripper inside tol * this


def expert_action(env: PointMassEnv, rng: np.random.Generator) -> np.ndarray:
    s = env.spec
    act = np.zeros(s.action_dim)
    leg = s.legs[env.leg_idx] if env.leg_idx < len(s.legs) else None
    for a in range(s.n_arms):
        if leg is not None and leg.arm == a:
            if leg.kind == LEG_GRASP:
                tgt = env.obj
                close = _dist(env.ee[a], env.obj) < leg.tol * GRIP_CLOSE_FRAC
                grip_cmd = 1.0 if close else -1.0
            elif leg.kind == LEG_VIA:
                tgt = np.asarray(leg.target)
                grip_cmd = 1.0
            else:  # PLACE
                tgt = np.asarray(leg.target)
                near = _dist(env.ee[a], tgt) < leg.tol * GRIP_CLOSE_FRAC
                grip_cmd = -1.0 if near else 1.0
        else:
            # idle arm: pre-position at its next leg's target (or stay)
            tgt = _next_target_for_arm(env, a)
            grip_cmd = -1.0
        delta = np.clip(KP * (tgt - env.ee[a]), -1.0, 1.0)
        act[7 * a: 7 * a + 2] = delta
        act[7 * a + 2] = grip_cmd
    act = act + s.expert_noise * rng.standard_normal(s.action_dim)
    return np.clip(act, -1.0, 1.0)


def _next_target_for_arm(env: PointMassEnv, arm: int) -> np.ndarray:
    for leg in env.spec.legs[env.leg_idx:]:
        if leg.arm == arm:
            return env.obj if leg.kind == LEG_GRASP else np.asarray(leg.target)
    return env.ee[arm]


def collect_demos(spec: TaskSpec, n_episodes: int, seed: int):
    """Run the scripted expert; returns (obs, chunks) arrays for BC.

    obs: (N, obs_dim); chunks: (N, CHUNK * action_dim) — the CHUNK actions
    following each visited state (padded by repeating the last action).
    Episodes that fail are discarded (BC on successes only).
    """
    rng = np.random.default_rng(seed)
    env = PointMassEnv(spec)
    all_obs, all_chunks, n_ok = [], [], 0
    while n_ok < n_episodes:
        obs_list, act_list = [], []
        env.reset(rng)
        while not env.done:
            obs_list.append(env.obs())
            a = expert_action(env, rng)
            act_list.append(a)
            env.step(a)
        if not env.success:
            continue
        n_ok += 1
        acts = np.asarray(act_list)
        pad = np.repeat(acts[-1:], CHUNK, axis=0)
        acts = np.concatenate([acts, pad], axis=0)
        for t, o in enumerate(obs_list):
            all_obs.append(o)
            all_chunks.append(acts[t: t + CHUNK].ravel())
    return np.asarray(all_obs), np.asarray(all_chunks)
