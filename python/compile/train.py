"""Build-time trainer for every denoiser variant (x0-prediction DDPM).

Runs ONCE inside `make artifacts` (never on the request path). Uses the
pure-jnp forward (`denoise_ref`) — numerically identical to the Pallas
path (pinned by pytest) but fast to jit on the 1-core CPU testbed. Adam
is hand-rolled (no optax in the offline environment).
"""

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import envs, targets
from .model import ModelConfig, denoise_ref, init_params
from .schedule import make_schedule
from .variants import Variant


# ---------------------------------------------------------------------------
# Data plumbing: each variant yields (x0, cond) training batches
# ---------------------------------------------------------------------------

def make_dataset(variant: Variant, rng: np.random.Generator):
    """Returns sample_batch(n) -> (x0 (n,d) f32, cond (n,cond_dim) f32)."""
    t = variant.target
    if t == "gmm2d":
        def batch(n):
            return (targets.gmm2d_sample(rng, n).astype(np.float32),
                    np.zeros((n, 0), np.float32))
    elif t == "latent16":
        def batch(n):
            x, cls = targets.latent16_sample(rng, n)
            cond = np.eye(targets.LATENT16_CLASSES, dtype=np.float32)[cls]
            return x.astype(np.float32), cond
    elif t == "pixel64":
        def batch(n):
            return (targets.pixel64_sample(rng, n).astype(np.float32),
                    np.zeros((n, 0), np.float32))
    elif t == "env":
        spec = envs.TASKS[variant.env]
        obs, chunks = envs.collect_demos(spec, variant.demos, variant.seed)
        obs = obs.astype(np.float32)
        chunks = chunks.astype(np.float32)
        print(f"  demos: {len(obs)} transitions from {variant.demos} episodes")

        def batch(n):
            idx = rng.integers(0, len(obs), size=n)
            # DART-style robustness: jitter the conditioning observation
            # so the policy stays on-task under compounding rollout drift
            jitter = 0.01 * rng.standard_normal((n, obs.shape[1]))
            return chunks[idx], (obs[idx] + jitter).astype(np.float32)
    else:
        raise ValueError(f"unknown target {t}")
    return batch


# ---------------------------------------------------------------------------
# Hand-rolled Adam
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = lambda p: [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in p]
    return zeros(params), zeros(params)


@functools.partial(jax.jit, static_argnames=("lr", "b1", "b2", "eps"))
def adam_update(params, grads, m, v, step, lr=1e-3, b1=0.9, b2=0.999,
                eps=1e-8):
    bias1 = 1.0 - b1 ** step
    bias2 = 1.0 - b2 ** step

    def upd(p, g, m_i, v_i):
        m_n = b1 * m_i + (1 - b1) * g
        v_n = b2 * v_i + (1 - b2) * g * g
        p_n = p - lr * (m_n / bias1) / (jnp.sqrt(v_n / bias2) + eps)
        return p_n, m_n, v_n

    new_p, new_m, new_v = [], [], []
    for (w, b), (gw, gb), (mw, mb), (vw, vb) in zip(params, grads, m, v):
        w2, mw2, vw2 = upd(w, gw, mw, vw)
        b2_, mb2, vb2 = upd(b, gb, mb, vb)
        new_p.append((w2, b2_))
        new_m.append((mw2, mb2))
        new_v.append((vw2, vb2))
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# Training loop
# ---------------------------------------------------------------------------

def train_variant(variant: Variant) -> Tuple[list, float]:
    """Trains one denoiser; returns (params, final_loss)."""
    cfg: ModelConfig = variant.cfg
    sched = make_schedule(cfg.k_steps)
    sqrt_abar = jnp.asarray(np.sqrt(sched["abar"]), jnp.float32)
    sqrt_1m = jnp.asarray(np.sqrt(1.0 - sched["abar"]), jnp.float32)

    rng = np.random.default_rng(variant.seed)
    batch_fn = make_dataset(variant, rng)
    params = [(jnp.asarray(w), jnp.asarray(b))
              for w, b in init_params(cfg, variant.seed)]
    m, v = adam_init(params)

    def loss_fn(p, x0, cond, t_idx, eps):
        # forward-noise x0 to step t (t_idx is 0-based into the tables)
        y = sqrt_abar[t_idx][:, None] * x0 + sqrt_1m[t_idx][:, None] * eps
        pred = denoise_ref(p, y, (t_idx + 1).astype(jnp.float32), cond, cfg)
        return jnp.mean(jnp.sum((pred - x0) ** 2, axis=-1))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    loss_val = float("nan")
    ema_loss = None
    for step in range(1, variant.train_steps + 1):
        x0, cond = batch_fn(variant.batch_size)
        t_idx = jnp.asarray(
            rng.integers(0, cfg.k_steps, size=variant.batch_size))
        eps = jnp.asarray(
            rng.standard_normal((variant.batch_size, cfg.d)), jnp.float32)
        loss_val, grads = grad_fn(params, jnp.asarray(x0), jnp.asarray(cond),
                                  t_idx, eps)
        params, m, v = adam_update(params, grads, m, v, step, lr=variant.lr)
        loss_f = float(loss_val)
        ema_loss = loss_f if ema_loss is None else 0.98 * ema_loss + 0.02 * loss_f
        if step % 1000 == 0 or step == 1:
            print(f"  step {step:5d}  loss {loss_f:.4f}  ema {ema_loss:.4f}")
    return [(np.asarray(w), np.asarray(b)) for w, b in params], float(ema_loss)
