"""Reference (numpy) implementation of Autospeculative Decoding (Alg 1-3).

This is the executable specification for the Rust engine
(rust/src/asd/engine.rs): same DDPM-native formulation (Remark 2), same
randomness contract (pre-drawn per-step (u_i, xi_i) streams indexed by the
DDPM step they will be consumed at), same round accounting. pytest checks
it against sequential DDPM for distributional equality and Lemma-13
invariants; the Rust integration tests reproduce its traces.

Step indexing: DDPM indices run i = K, K-1, ..., 1; transition i -> i-1
consumes (u[i-1], xi[i-1]) (0-based arrays of length K) and the schedule
row i-1 of (c1, c2, sigma) from schedule.make_schedule.
"""

import dataclasses
from typing import Callable, Optional

import numpy as np

from .schedule import make_schedule

_SIGMA0_TOL = 1e-6
_EPS = 1e-12


@dataclasses.dataclass
class AsdStats:
    model_calls: int = 0       # total denoiser evaluations
    parallel_rounds: int = 0   # rounds of (possibly batched) calls
    iterations: int = 0
    accepted: int = 0
    rejected: int = 0


def grs(u, xi, m_hat, m, sigma):
    """Gaussian rejection sampler (Alg 3); returns (z, accept)."""
    v = m_hat - m
    v_sq = float(np.dot(v, v))
    if sigma <= _SIGMA0_TOL:
        return m.copy(), v_sq <= _SIGMA0_TOL * _SIGMA0_TOL
    w_sq = v_sq / (sigma * sigma)
    log_ratio = -(np.dot(v, xi) / sigma + 0.5 * w_sq)
    accept = np.log(max(u, _EPS)) <= log_ratio or v_sq <= _EPS
    if accept:
        return m_hat + sigma * xi, True
    refl = xi - 2.0 * v * (np.dot(v, xi) / max(v_sq, _EPS))
    return m + sigma * refl, False


def sequential_ddpm(model: Callable, y_k: np.ndarray, k_steps: int,
                    sched, xi: np.ndarray) -> np.ndarray:
    """Baseline ancestral sampler; model(y, i) -> x0hat; K model calls."""
    y = y_k.copy()
    for i in range(k_steps, 0, -1):
        x0 = model(y, i)
        j = i - 1
        y = sched["c1"][j] * x0 + sched["c2"][j] * y
        if sched["sigma"][j] > 0:
            y = y + sched["sigma"][j] * xi[j]
    return y


def asd(model: Callable, batch_model: Optional[Callable], y_k: np.ndarray,
        k_steps: int, sched, u: np.ndarray, xi: np.ndarray, theta: int,
        eval_tail: bool = True):
    """Autospeculative decoding (Alg 1). Returns (y_0, AsdStats).

    model(y, i) -> x0hat; batch_model(ys (n,d), is (n,)) -> (n,d) or None
    to loop over `model`. theta <= 0 means ASD-infinity (speculate to the
    end). ``eval_tail`` additionally evaluates the chain's final point in
    the verify round so a fully-accepted window chains into the next
    proposal for free (DESIGN.md §2).
    """
    if batch_model is None:
        def batch_model(ys, idxs):
            return np.stack([model(ys[r], int(idxs[r]))
                             for r in range(len(ys))])

    c1, c2, sigma = sched["c1"], sched["c2"], sched["sigma"]
    stats = AsdStats()
    y = y_k.copy()
    i_cur = k_steps
    x0_cur = None  # x0hat at (y, i_cur) when already known
    while i_cur > 0:
        stats.iterations += 1
        th = i_cur if theta <= 0 else min(theta, i_cur)

        # --- proposal round: one model call (unless chained from verify)
        if x0_cur is None:
            x0a = model(y, i_cur)
            stats.model_calls += 1
            stats.parallel_rounds += 1
        else:
            x0a = x0_cur

        # --- speculate (kernel `speculate`): chain positions k = 0..th-1
        # cover transitions j -> j-1 for j = i_cur - k
        js = i_cur - np.arange(th)            # DDPM indices of transitions
        m_hat = np.empty((th, len(y)))
        y_hat = np.empty((th, len(y)))
        y_prev = y
        for k in range(th):
            j = js[k] - 1                      # schedule/noise row
            m_hat[k] = c1[j] * x0a + c2[j] * y_prev
            y_hat[k] = m_hat[k] + sigma[j] * xi[j]
            y_prev = y_hat[k]

        # --- verify round: one *parallel* batch of model calls at the
        # proposed points (chain positions 1..th-1; position 0 reuses x0a
        # — that is Lemma 13), plus optionally the tail point.
        eval_pos = list(range(1, th))
        tail = eval_tail and js[-1] - 1 > 0
        ys_eval = [y_hat[k - 1] for k in eval_pos]
        idx_eval = [js[k] for k in eval_pos]
        if tail:
            ys_eval.append(y_hat[th - 1])
            idx_eval.append(js[th - 1] - 1)
        if ys_eval:
            x0_eval = batch_model(np.stack(ys_eval), np.asarray(idx_eval))
            stats.model_calls += len(ys_eval)
            stats.parallel_rounds += 1
        else:
            x0_eval = np.zeros((0, len(y)))

        x0_at = {0: x0a}
        for n, k in enumerate(eval_pos):
            x0_at[k] = x0_eval[n]
        x0_tail = x0_eval[-1] if tail else None

        # --- verifier (Alg 2): sequential-scan semantics over parallel GRS
        advanced = 0
        x0_next = None
        for k in range(th):
            j = js[k] - 1
            y_base = y if k == 0 else y_hat[k - 1]
            m = c1[j] * x0_at[k] + c2[j] * y_base
            z, ok = grs(u[j], xi[j], m_hat[k], m, sigma[j])
            if ok:
                stats.accepted += 1
                y = z
                advanced += 1
                if k == th - 1 and tail:
                    x0_next = x0_tail  # accepted tail: z == y_hat[th-1]
            else:
                stats.rejected += 1
                y = z                 # reflected sample — still exact
                advanced += 1
                break
        i_cur -= advanced
        x0_cur = x0_next
    return y, stats
