"""Build-path Python package: L2 JAX model + L1 Pallas kernels + AOT.

Nothing in this package is imported at runtime; `aot.py` lowers everything
to HLO text artifacts that the Rust coordinator loads via PJRT.
"""
