"""L1 Pallas kernel: batched Gaussian Rejection Sampler (Algorithm 3).

For each of T speculative steps, given the proposal mean ``m_hat``, the
target mean ``m`` (both Gaussians share variance ``sigma^2 I``), the
pre-drawn noise ``xi ~ N(0, I)`` and uniform seed ``u``:

    v = m_hat - m,  w = v / sigma
    accept  <=>  u <= min(1, N(xi + w | 0, I) / N(xi | 0, I))
            <=>  log u <= -(||xi + w||^2 - ||xi||^2) / 2
                        = -<w, xi> - ||w||^2 / 2
    accepted:  z = m_hat + sigma * xi           (the proposal sample)
    rejected:  z = m + sigma * reflect(xi)      (reflection coupling)
               reflect(xi) = xi - 2 v <v, xi> / ||v||^2

Theorem 12: z ~ N(m, sigma^2 I) exactly in both branches, and
P[reject] = TV(N(m_hat, s^2 I), N(m, s^2 I)). Edge cases handled exactly:

* ||v|| = 0: accept always (ratio = 1, u <= 1); reflection undefined but
  unused. This is what makes the first speculated step always accepted
  (Lemma 13).
* sigma = 0 (final DDPM step): distributions are Diracs; accept iff
  m_hat == m (within eps); z = m either way.

All T verifications are independent given their inputs — the kernel is a
pure row-parallel VPU workload ((T, d) elementwise ops + per-row
reductions), an ideal single-block Pallas kernel.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12
_SIGMA0_TOL = 1e-6


def _grs_kernel(u_ref, xi_ref, m_hat_ref, m_ref, sigma_ref, z_ref, acc_ref):
    u = u_ref[...]              # (T,)
    xi = xi_ref[...]            # (T, d)
    m_hat = m_hat_ref[...]      # (T, d)
    m = m_ref[...]              # (T, d)
    sigma = sigma_ref[...]      # (T,)

    v = m_hat - m                                        # (T, d)
    v_sq = jnp.sum(v * v, axis=-1)                       # (T,)
    safe_sigma = jnp.maximum(sigma, _EPS)
    w = v / safe_sigma[:, None]
    w_sq = v_sq / (safe_sigma * safe_sigma)
    # log acceptance ratio, clipped at 0
    log_ratio = -(jnp.sum(w * xi, axis=-1) + 0.5 * w_sq)
    accept_gauss = jnp.log(jnp.maximum(u, _EPS)) <= log_ratio

    # reflection of xi along v (guard v=0; the branch is unused there)
    vxi = jnp.sum(v * xi, axis=-1)
    refl = xi - 2.0 * v * (vxi / jnp.maximum(v_sq, _EPS))[:, None]

    z_acc = m_hat + sigma[:, None] * xi
    z_rej = m + sigma[:, None] * refl

    # sigma == 0: Dirac case
    is_dirac = sigma <= _SIGMA0_TOL
    accept_dirac = v_sq <= _SIGMA0_TOL * _SIGMA0_TOL
    accept = jnp.where(is_dirac, accept_dirac, accept_gauss | (v_sq <= _EPS))
    z = jnp.where(accept[:, None], z_acc, z_rej)
    z = jnp.where(is_dirac[:, None], m, z)

    z_ref[...] = z
    acc_ref[...] = accept.astype(jnp.float32)


@jax.jit
def grs_verify(u: jax.Array, xi: jax.Array, m_hat: jax.Array, m: jax.Array,
               sigma: jax.Array):
    """Batched GRS over T speculative steps.

    Args:
      u: (T,) uniform seeds in [0, 1].
      xi: (T, d) standard normal noise (same stream used by `speculate`).
      m_hat: (T, d) proposal means.
      m: (T, d) target means.
      sigma: (T,) per-step standard deviations.

    Returns:
      z: (T, d) corrected samples, each ~ N(m_k, sigma_k^2 I).
      accept: (T,) float32 in {0, 1}.
    """
    t_steps, d = xi.shape
    assert u.shape == sigma.shape == (t_steps,)
    assert m_hat.shape == m.shape == (t_steps, d)
    return pl.pallas_call(
        _grs_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((t_steps, d), jnp.float32),
            jax.ShapeDtypeStruct((t_steps,), jnp.float32),
        ),
        interpret=True,
    )(u, xi, m_hat, m, sigma)
