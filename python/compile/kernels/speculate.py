"""L1 Pallas kernel: ASD proposal chain (Algorithm 1, lines 7-9).

Given the current iterate ``y_a`` at DDPM index ``a`` and the single model
prediction ``x0a = x_hat_0(y_a, a)``, speculate the next ``T`` denoising
steps by freezing the model output (hidden exchangeability / Remark 2 of
the paper): for chain position ``k`` (step index ``j = a - k``):

    m_hat[k] = c1[k] * x0a + c2[k] * y[k-1]        (y[-1] = y_a)
    y_hat[k] = m_hat[k] + sigma[k] * xi[k]

This is a *linear recurrence* ``y_k = A_k y_{k-1} + u_k`` with scalar
``A_k = c2[k]`` and ``u_k = c1[k] x0a + sigma[k] xi[k]``; the paper notes
it is computable in O~(1) parallel time via prefix sums (associative scan
over (A, u) pairs — that formulation is the oracle in ``ref.py``). The
kernel below evaluates the recurrence with an in-VMEM ``fori_loop``: for
T <= 64 and d <= 256 the whole chain state is a single VMEM block, so the
sequential-in-k loop is latency-bound at ~T cycles of VPU work, which is
negligible next to the denoiser matmuls it feeds.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _speculate_kernel(y_a_ref, x0a_ref, c1_ref, c2_ref, sigma_ref, xi_ref,
                      m_hat_ref, y_hat_ref):
    y_a = y_a_ref[...]          # (d,)
    x0a = x0a_ref[...]          # (d,)
    c1 = c1_ref[...]            # (T,)
    c2 = c2_ref[...]            # (T,)
    sigma = sigma_ref[...]      # (T,)
    xi = xi_ref[...]            # (T, d)
    t_steps = c1.shape[0]

    def body(k, y_prev):
        m_hat = c1[k] * x0a + c2[k] * y_prev
        y_hat = m_hat + sigma[k] * xi[k]
        m_hat_ref[k, :] = m_hat
        y_hat_ref[k, :] = y_hat
        return y_hat

    jax.lax.fori_loop(0, t_steps, body, y_a)


@jax.jit
def speculate(y_a: jax.Array, x0a: jax.Array, c1: jax.Array, c2: jax.Array,
              sigma: jax.Array, xi: jax.Array):
    """Proposal chain for T speculative steps.

    Args:
      y_a: (d,) current iterate.
      x0a: (d,) model prediction at the current iterate.
      c1, c2, sigma: (T,) per-step DDPM posterior coefficients
        (``schedule.py`` / rust ``schedule::ddpm`` produce these).
      xi: (T, d) pre-drawn standard normal noise (rust owns randomness).

    Returns:
      (m_hat, y_hat): each (T, d); proposal means and proposal samples.
    """
    t_steps, d = xi.shape
    assert y_a.shape == (d,) and x0a.shape == (d,)
    assert c1.shape == c2.shape == sigma.shape == (t_steps,)
    return pl.pallas_call(
        _speculate_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((t_steps, d), jnp.float32),
            jax.ShapeDtypeStruct((t_steps, d), jnp.float32),
        ),
        interpret=True,
    )(y_a, x0a, c1, c2, sigma, xi)
