"""L1 Pallas kernel: fused linear layer `act(x @ W + b)`.

This is the hot block of the denoiser MLP (L2 `model.py`). It is written
as a Pallas kernel so the whole denoiser lowers into a single HLO module
that the Rust runtime executes via PJRT.

TPU mapping (see DESIGN.md §Hardware-Adaptation): on a real TPU this
kernel tiles `x` into (8, 128)-aligned VMEM blocks, keeps `W` resident in
VMEM across the batch (weights for our largest layer are 256*256*4 B =
256 KiB, ~1.6% of a 16 MiB VMEM), and drives the MXU with bf16 matmuls.
On this CPU testbed it must run with `interpret=True` (real TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute); numerics
are identical, and correctness is pinned against `ref.py` by pytest.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Activation tags understood by the kernel.
ACT_NONE = 0
ACT_SILU = 1


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, act: int):
    """o = act(x @ W + b), single-block version.

    BlockSpec note: our denoiser shapes (B <= 64, n_in/n_out <= 512) fit a
    single VMEM block with large headroom, so the grid is trivial; the
    block-tiled variant for larger shapes would split `x` on the batch
    axis and `W` on the output axis with a (B_tile, 128) x (128, O_tile)
    MXU schedule.
    """
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if act == ACT_SILU:
        y = y * jax.nn.sigmoid(y)
    o_ref[...] = y


@partial(jax.jit, static_argnames=("act",))
def fused_linear(x: jax.Array, w: jax.Array, b: jax.Array, act: int = ACT_SILU):
    """Fused `act(x @ W + b)` via Pallas (interpret mode on CPU).

    Args:
      x: (B, n_in) f32 activations.
      w: (n_in, n_out) f32 weights.
      b: (n_out,) f32 bias.
      act: ACT_NONE or ACT_SILU.

    Returns:
      (B, n_out) f32.
    """
    batch, n_in = x.shape
    n_in_w, n_out = w.shape
    assert n_in == n_in_w, f"shape mismatch: x {x.shape} vs w {w.shape}"
    assert b.shape == (n_out,)
    return pl.pallas_call(
        partial(_fused_linear_kernel, act=act),
        out_shape=jax.ShapeDtypeStruct((batch, n_out), jnp.float32),
        interpret=True,
    )(x, w, b)
