"""Pure-jnp oracles for every L1 Pallas kernel.

These are the CORE correctness signal: pytest asserts each Pallas kernel
matches its oracle across hypothesis-swept shapes (see
python/tests/test_kernels.py), and the Rust integration tests compare the
AOT-compiled HLO against the same numbers.
"""

import jax
import jax.numpy as jnp

_EPS = 1e-12
_SIGMA0_TOL = 1e-6


def fused_linear_ref(x, w, b, act: int = 1):
    """act(x @ W + b); act: 0 = none, 1 = SiLU."""
    y = x @ w + b[None, :]
    if act == 1:
        y = y * jax.nn.sigmoid(y)
    return y


def speculate_ref(y_a, x0a, c1, c2, sigma, xi):
    """Proposal chain via lax.scan (sequential reference)."""

    def step(y_prev, inp):
        c1_k, c2_k, s_k, xi_k = inp
        m_hat = c1_k * x0a + c2_k * y_prev
        y_hat = m_hat + s_k * xi_k
        return y_hat, (m_hat, y_hat)

    _, (m_hat, y_hat) = jax.lax.scan(step, y_a, (c1, c2, sigma, xi))
    return m_hat, y_hat


def speculate_prefix_ref(y_a, x0a, c1, c2, sigma, xi):
    """Proposal chain via associative scan — the paper's O~(1) parallel
    prefix-sum formulation. Recurrence y_k = A_k y_{k-1} + u_k composes
    as (A, u) o (A', u') = (A A', A' u + u'), an associative monoid.
    """
    u = c1[:, None] * x0a[None, :] + sigma[:, None] * xi  # (T, d)
    a = c2  # (T,)

    def combine(left, right):
        a_l, u_l = left
        a_r, u_r = right
        return a_l * a_r, a_r[:, None] * u_l + u_r

    a_pref, u_pref = jax.lax.associative_scan(combine, (a, u))
    y_hat = a_pref[:, None] * y_a[None, :] + u_pref
    m_hat = y_hat - sigma[:, None] * xi
    return m_hat, y_hat


def grs_verify_ref(u, xi, m_hat, m, sigma):
    """Batched Gaussian rejection sampler, mirroring kernels/grs.py."""
    v = m_hat - m
    v_sq = jnp.sum(v * v, axis=-1)
    safe_sigma = jnp.maximum(sigma, _EPS)
    w = v / safe_sigma[:, None]
    w_sq = v_sq / (safe_sigma * safe_sigma)
    log_ratio = -(jnp.sum(w * xi, axis=-1) + 0.5 * w_sq)
    accept_gauss = jnp.log(jnp.maximum(u, _EPS)) <= log_ratio

    vxi = jnp.sum(v * xi, axis=-1)
    refl = xi - 2.0 * v * (vxi / jnp.maximum(v_sq, _EPS))[:, None]
    z_acc = m_hat + sigma[:, None] * xi
    z_rej = m + sigma[:, None] * refl

    is_dirac = sigma <= _SIGMA0_TOL
    accept_dirac = v_sq <= _SIGMA0_TOL * _SIGMA0_TOL
    accept = jnp.where(is_dirac, accept_dirac, accept_gauss | (v_sq <= _EPS))
    z = jnp.where(accept[:, None], z_acc, z_rej)
    z = jnp.where(is_dirac[:, None], m, z)
    return z, accept.astype(jnp.float32)
