"""L1 Pallas kernels for the ASD hot path (+ pure-jnp oracles in ref.py)."""

from .fused_linear import ACT_NONE, ACT_SILU, fused_linear
from .grs import grs_verify
from .speculate import speculate

__all__ = ["fused_linear", "ACT_NONE", "ACT_SILU", "grs_verify", "speculate"]
