"""DDPM noise schedule + Stochastic Localization (SL) reparametrization.

Source of truth for the schedule constants; `aot.py` exports the exact
arrays into artifacts/manifest.json and the Rust `schedule` module
cross-checks its own computation against them (tested to 1e-6).

Conventions (paper Sec. 3, Remark 2; x0-prediction form):

  forward:   y_i = sqrt(abar_i) x0 + sqrt(1 - abar_i) eps,  i in 1..K
  reverse:   y_{i-1} = c1_i x0hat(y_i, i) + c2_i y_i + sigma_i xi
     c1_i    = sqrt(abar_{i-1}) beta_i / (1 - abar_i)
     c2_i    = sqrt(alpha_i) (1 - abar_{i-1}) / (1 - abar_i)
     sigma_i = sqrt((1 - abar_{i-1}) beta_i / (1 - abar_i))   (abar_0 = 1)

sigma_1 = 0: the final step is deterministic (Dirac; GRS handles it).

SL equivalence (Thm 9): ybar_t = t e^{s(t)} xbar_{s(t)} with
s(t) = ln(1 + 1/t) / 2; used by the theory benches (rust schedule::sl).
"""

import numpy as np

BETA_START = 1e-4
BETA_END = 2e-2
REF_STEPS = 1000  # schedule is defined at 1000 steps and rescaled


def make_betas(k_steps: int) -> np.ndarray:
    """Linear-beta schedule, rescaled so total noising matches K=1000.

    For K < 1000 (robot policies use K=100) the betas are scaled by
    1000/K so abar_K stays ~0 — the same convention diffusers uses when
    retraining with fewer steps.
    """
    scale = REF_STEPS / k_steps
    betas = np.linspace(BETA_START * scale, BETA_END * scale, k_steps,
                        dtype=np.float64)
    # K < ~20 would push beta past 1; clamp (alphas must stay positive)
    return np.minimum(betas, 0.999)


def make_schedule(k_steps: int):
    """Returns dict of f64 arrays, each of length K, indexed by i-1 for
    step i in 1..K: betas, alphas, abar, c1, c2, sigma, and abar_prev."""
    betas = make_betas(k_steps)
    alphas = 1.0 - betas
    abar = np.cumprod(alphas)
    abar_prev = np.concatenate([[1.0], abar[:-1]])
    c1 = np.sqrt(abar_prev) * betas / (1.0 - abar)
    c2 = np.sqrt(alphas) * (1.0 - abar_prev) / (1.0 - abar)
    sigma = np.sqrt((1.0 - abar_prev) * betas / (1.0 - abar))
    return {
        "betas": betas,
        "alphas": alphas,
        "abar": abar,
        "abar_prev": abar_prev,
        "c1": c1,
        "c2": c2,
        "sigma": sigma,
    }


def sl_time_of_ddpm(s: np.ndarray) -> np.ndarray:
    """t(s) = 1 / (e^{2s} - 1): inverse of s(t) = ln(1 + 1/t)/2."""
    return 1.0 / np.expm1(2.0 * s)


def ddpm_time_of_sl(t: np.ndarray) -> np.ndarray:
    """s(t) = ln(1 + 1/t) / 2 (Thm 9)."""
    return 0.5 * np.log1p(1.0 / t)
