"""Golden traces for Rust parity tests (artifacts/golden.json).

Generated at build time alongside the HLO artifacts:

* env traces      — explicit initial state + action sequence + expected
                    observation/state sequence per task (rust env must
                    reproduce bit-for-bit up to f64 rounding).
* model forwards  — (y, t, cond) -> x0hat tuples per variant (checks the
                    rust HLO execution AND the rust-native MLP oracle).
* schedule spots  — c1/c2/sigma at sampled indices.
* asd trace       — full ASD run on gmm2d with explicit (u, xi) streams;
                    rust must reproduce the final sample and stats.
"""

import json
import os

import numpy as np

from . import envs
from .asd_ref import asd, sequential_ddpm
from .model import denoise_ref
from .schedule import make_schedule
from .variants import VARIANTS


def env_traces():
    out = {}
    for name, spec in envs.TASKS.items():
        rng = np.random.default_rng(2024)
        env = envs.PointMassEnv(spec)
        env.reset(rng)
        init = {"ee": env.ee.tolist(), "obj": env.obj.tolist()}
        actions, obs_seq = [], [env.obs().tolist()]
        arng = np.random.default_rng(77)
        for t in range(40):
            a = envs.expert_action(env, arng)
            actions.append(a.tolist())
            obs_seq.append(env.step(a).tolist())
        out[name] = {
            "init": init,
            "actions": actions,
            "obs": obs_seq,
            "leg_idx": env.leg_idx,
            "carried": env.carried,
            "failed": env.failed,
            "obs_dim": spec.obs_dim,
            "action_dim": spec.action_dim,
        }
    return out


def model_forward_goldens(trained):
    """trained: {name: params}; 3 probe points per variant."""
    out = {}
    for name, params in trained.items():
        cfg = VARIANTS[name].cfg
        rng = np.random.default_rng(hash(name) % (2**31))
        cases = []
        for _ in range(3):
            y = rng.standard_normal((2, cfg.d)).astype(np.float32)
            t = rng.integers(1, cfg.k_steps + 1, 2).astype(np.float32)
            cond = rng.standard_normal((2, cfg.cond_dim)).astype(np.float32)
            x0 = np.asarray(denoise_ref(
                [(w, b) for w, b in params], y, t, cond, cfg))
            cases.append({"y": y.tolist(), "t": t.tolist(),
                          "cond": cond.tolist(), "x0": x0.tolist()})
        out[name] = cases
    return out


def schedule_spots():
    out = {}
    for k in (100, 1000):
        s = make_schedule(k)
        idx = [0, 1, k // 2, k - 1]
        out[str(k)] = {
            "idx": idx,
            "c1": [s["c1"][i] for i in idx],
            "c2": [s["c2"][i] for i in idx],
            "sigma": [s["sigma"][i] for i in idx],
            "abar": [s["abar"][i] for i in idx],
        }
    return out


def asd_trace(trained):
    """Golden ASD + sequential run on gmm2d with the trained network."""
    name = "gmm2d"
    if name not in trained:
        return None
    params = [(w, b) for w, b in trained[name]]
    cfg = VARIANTS[name].cfg
    sched = make_schedule(cfg.k_steps)

    def model(y, i):
        out = denoise_ref(params, y[None].astype(np.float32),
                          np.asarray([float(i)], np.float32),
                          np.zeros((1, 0), np.float32), cfg)
        return np.asarray(out)[0].astype(np.float64)

    rng = np.random.default_rng(31337)
    y_k = rng.standard_normal(cfg.d)
    xi = rng.standard_normal((cfg.k_steps, cfg.d))
    u = rng.uniform(0, 1, cfg.k_steps)
    y_seq = sequential_ddpm(model, y_k, cfg.k_steps, sched, xi)
    traces = {}
    for theta in (4, 8, 0):
        y0, st = asd(model, None, y_k, cfg.k_steps, sched, u, xi, theta)
        traces[str(theta)] = {
            "y0": y0.tolist(),
            "model_calls": st.model_calls,
            "parallel_rounds": st.parallel_rounds,
            "iterations": st.iterations,
            "accepted": st.accepted,
            "rejected": st.rejected,
        }
    return {
        "variant": name,
        "y_k": y_k.tolist(),
        "xi": xi.tolist(),
        "u": u.tolist(),
        "sequential_y0": y_seq.tolist(),
        "asd": traces,
    }


def write_golden(out_dir: str, trained):
    data = {
        "envs": env_traces(),
        "model_forwards": model_forward_goldens(trained),
        "schedule": schedule_spots(),
        "asd_gmm2d": asd_trace(trained),
    }
    path = os.path.join(out_dir, "golden.json")
    # partial rebuilds (aot --only ...) must not lose other variants'
    # forwards or the gmm2d ASD trace
    if os.path.exists(path):
        with open(path) as f:
            old = json.load(f)
        merged = old.get("model_forwards", {})
        merged.update(data["model_forwards"])
        data["model_forwards"] = merged
        if data["asd_gmm2d"] is None:
            data["asd_gmm2d"] = old.get("asd_gmm2d")
    with open(path, "w") as f:
        json.dump(data, f)
    print(f"[golden] wrote {path}")
