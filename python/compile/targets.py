"""Synthetic target distributions (the paper's datasets, substituted).

DESIGN.md §4 documents each substitution. Every target here has an exact
mirror in rust/src/model/targets.rs (distribution-identical sampler +
ground-truth statistics) so the Rust quality metrics (CLIP-proxy
alignment, FID-proxy Frechet) are computed against the true target.

All parameters are deterministic functions of fixed seeds and are exported
into artifacts/manifest.json.
"""

import numpy as np

# ---------------------------------------------------------------------------
# gmm2d: 8 isotropic Gaussians on a circle (unconditional quickstart target)
# ---------------------------------------------------------------------------

GMM2D_COMPONENTS = 8
GMM2D_RADIUS = 1.5
GMM2D_SIGMA = 0.12


def gmm2d_params():
    ang = 2.0 * np.pi * np.arange(GMM2D_COMPONENTS) / GMM2D_COMPONENTS
    means = np.stack([GMM2D_RADIUS * np.cos(ang),
                      GMM2D_RADIUS * np.sin(ang)], axis=1)
    sigmas = np.full(GMM2D_COMPONENTS, GMM2D_SIGMA)
    weights = np.full(GMM2D_COMPONENTS, 1.0 / GMM2D_COMPONENTS)
    return means, sigmas, weights


def gmm2d_sample(rng: np.random.Generator, n: int):
    means, sigmas, weights = gmm2d_params()
    comp = rng.choice(len(weights), size=n, p=weights)
    return means[comp] + sigmas[comp, None] * rng.standard_normal((n, 2))


# ---------------------------------------------------------------------------
# latent16: 10-class conditional GMM in R^16 (StableDiffusion-latent stand-in)
# ---------------------------------------------------------------------------

LATENT16_DIM = 16
LATENT16_CLASSES = 10
LATENT16_SIGMA = 0.35
LATENT16_SCALE = 2.0
_LATENT16_SEED = 1234


def latent16_params():
    rng = np.random.default_rng(_LATENT16_SEED)
    raw = rng.standard_normal((LATENT16_CLASSES, LATENT16_DIM))
    means = LATENT16_SCALE * raw / np.linalg.norm(raw, axis=1, keepdims=True)
    sigmas = np.full(LATENT16_CLASSES, LATENT16_SIGMA)
    weights = np.full(LATENT16_CLASSES, 1.0 / LATENT16_CLASSES)
    return means, sigmas, weights


def latent16_sample(rng: np.random.Generator, n: int, cls=None):
    """Class-conditional sample; cls None => classes drawn uniformly."""
    means, sigmas, _ = latent16_params()
    if cls is None:
        cls = rng.integers(0, LATENT16_CLASSES, size=n)
    else:
        cls = np.broadcast_to(np.asarray(cls), (n,))
    x = means[cls] + sigmas[cls, None] * rng.standard_normal(
        (n, LATENT16_DIM))
    return x, cls


# ---------------------------------------------------------------------------
# pixel64: procedural 8x8 "texture" images in [-1, 1]^64 (LSUN stand-in)
# ---------------------------------------------------------------------------

PIXEL64_SIDE = 8
PIXEL64_DIM = PIXEL64_SIDE * PIXEL64_SIDE
PIXEL64_FREQ_MIN = 1.0
PIXEL64_FREQ_MAX = 3.0
PIXEL64_AMP_MIN = 0.5
PIXEL64_AMP_MAX = 1.0
PIXEL64_NOISE = 0.05


def pixel64_sample(rng: np.random.Generator, n: int):
    """Oriented sinusoidal gratings with random frequency/phase/amplitude
    plus pixel noise. The Rust mirror (model/targets.rs) draws the same
    parameters from the same uniform/normal primitives."""
    freq = rng.uniform(PIXEL64_FREQ_MIN, PIXEL64_FREQ_MAX, size=n)
    psi = rng.uniform(0.0, np.pi, size=n)
    phase = rng.uniform(0.0, 2.0 * np.pi, size=n)
    amp = rng.uniform(PIXEL64_AMP_MIN, PIXEL64_AMP_MAX, size=n)
    ii, jj = np.meshgrid(np.arange(PIXEL64_SIDE), np.arange(PIXEL64_SIDE),
                         indexing="ij")
    grid = (np.cos(psi)[:, None, None] * ii[None] +
            np.sin(psi)[:, None, None] * jj[None]) / PIXEL64_SIDE
    img = amp[:, None, None] * np.sin(
        2.0 * np.pi * freq[:, None, None] * grid + phase[:, None, None])
    img = img + PIXEL64_NOISE * rng.standard_normal(img.shape)
    return img.reshape(n, PIXEL64_DIM)
