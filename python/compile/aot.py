"""AOT lowering: JAX/Pallas -> HLO text artifacts + manifest + weights.

Emits HLO **text** (NOT `.serialize()`): the image's xla_extension 0.5.1
rejects jax>=0.5's 64-bit-instruction-id protos; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written to ../artifacts (repo root):
  denoise_{variant}_b{B}.hlo.txt   (y[B,d], t[B], cond[B,c]?, *weights) -> x0hat
  speculate_d{d}_T{T}.hlo.txt      proposal chain (Pallas prefix kernel)
  verify_d{d}_T{T}.hlo.txt         batched GRS (Pallas kernel)
  weights_{variant}.bin            flat f32 (layout: model.flatten_params)
  manifest.json                    dims, schedules, targets, artifact map

Weights are HLO *parameters* (not baked constants): the Rust runtime
uploads them to device once per variant (PjRtBuffer) and reuses them for
every call via execute_b — keeping artifacts small and the request path
argument-light.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import envs, targets
from .kernels import grs_verify, speculate
from .model import denoise_pallas, flatten_params, layer_dims
from .schedule import BETA_END, BETA_START, make_schedule
from .train import train_variant
from .variants import BATCH_SIZES, SPEC_T, VARIANTS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_denoise(variant, params, batch: int) -> str:
    """Lower the Pallas denoiser for one batch size, weights as params."""
    cfg = variant.cfg
    n_weights = len(params)

    def fn(y, t, cond, *flat_w):
        p = [(flat_w[2 * i], flat_w[2 * i + 1]) for i in range(n_weights)]
        return (denoise_pallas(p, y, t, cond, cfg),)

    w_specs = []
    for w, b in params:
        w_specs.append(_spec(w.shape))
        w_specs.append(_spec(b.shape))
    lowered = jax.jit(fn).lower(
        _spec((batch, cfg.d)), _spec((batch,)),
        _spec((batch, cfg.cond_dim)), *w_specs)
    return to_hlo_text(lowered)


def lower_speculate(d: int, t_steps: int) -> str:
    def fn(y_a, x0a, c1, c2, sigma, xi):
        return speculate(y_a, x0a, c1, c2, sigma, xi)

    lowered = jax.jit(fn).lower(
        _spec((d,)), _spec((d,)), _spec((t_steps,)), _spec((t_steps,)),
        _spec((t_steps,)), _spec((t_steps, d)))
    return to_hlo_text(lowered)


def lower_verify(d: int, t_steps: int) -> str:
    def fn(u, xi, m_hat, m, sigma):
        return grs_verify(u, xi, m_hat, m, sigma)

    lowered = jax.jit(fn).lower(
        _spec((t_steps,)), _spec((t_steps, d)), _spec((t_steps, d)),
        _spec((t_steps, d)), _spec((t_steps,)))
    return to_hlo_text(lowered)


def target_manifest(variant) -> dict:
    """Ground-truth target parameters for the Rust quality metrics."""
    t = variant.target
    if t == "gmm2d":
        means, sigmas, weights = targets.gmm2d_params()
    elif t == "latent16":
        means, sigmas, weights = targets.latent16_params()
    elif t == "pixel64":
        return {"kind": "pixel64", "side": targets.PIXEL64_SIDE,
                "freq": [targets.PIXEL64_FREQ_MIN, targets.PIXEL64_FREQ_MAX],
                "amp": [targets.PIXEL64_AMP_MIN, targets.PIXEL64_AMP_MAX],
                "noise": targets.PIXEL64_NOISE}
    elif t == "env":
        return {"kind": "env", "task": variant.env}
    else:
        raise ValueError(t)
    return {"kind": "gmm", "means": means.tolist(),
            "sigmas": sigmas.tolist(), "weights": weights.tolist()}


def build(out_dir: str, only=None):
    os.makedirs(out_dir, exist_ok=True)
    trained = {}
    manifest = {
        "format_version": 1,
        "beta_start": BETA_START,
        "beta_end": BETA_END,
        "spec_t": SPEC_T,
        "batch_sizes": BATCH_SIZES,
        "chunk": envs.CHUNK,
        "exec_steps": envs.EXEC_STEPS,
        "variants": {},
        "kernels": {"speculate": {}, "verify": {}},
    }

    dims_needed = set()
    for name, variant in VARIANTS.items():
        if only and name not in only:
            continue
        cfg = variant.cfg
        print(f"[aot] training {name} (d={cfg.d}, K={cfg.k_steps})")
        t0 = time.time()
        params, final_loss = train_variant(variant)
        trained[name] = params
        print(f"[aot] trained {name} in {time.time() - t0:.1f}s "
              f"loss={final_loss:.4f}")

        wpath = f"weights_{name}.bin"
        flatten_params(params).tofile(os.path.join(out_dir, wpath))

        art = {}
        for b in BATCH_SIZES:
            fname = f"denoise_{name}_b{b}.hlo.txt"
            text = lower_denoise(variant, params, b)
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            art[str(b)] = fname
        print(f"[aot] lowered {len(BATCH_SIZES)} denoise artifacts for {name}")

        sched = make_schedule(cfg.k_steps)
        entry = {
            "d": cfg.d,
            "cond_dim": cfg.cond_dim,
            "hidden": cfg.hidden,
            "layers": cfg.layers,
            "temb_dim": 32,
            "k_steps": cfg.k_steps,
            "train_loss": final_loss,
            "weights": wpath,
            "weights_layout": [[a, b] for a, b in layer_dims(cfg)],
            "artifacts": art,
            "abar": sched["abar"].tolist(),
            "target": target_manifest(variant),
            "env": variant.env,
        }
        manifest["variants"][name] = entry
        dims_needed.add(cfg.d)

    for d in sorted(dims_needed):
        sp = f"speculate_d{d}_T{SPEC_T}.hlo.txt"
        with open(os.path.join(out_dir, sp), "w") as f:
            f.write(lower_speculate(d, SPEC_T))
        manifest["kernels"]["speculate"][str(d)] = sp
        vf = f"verify_d{d}_T{SPEC_T}.hlo.txt"
        with open(os.path.join(out_dir, vf), "w") as f:
            f.write(lower_verify(d, SPEC_T))
        manifest["kernels"]["verify"][str(d)] = vf
        print(f"[aot] lowered speculate/verify kernels for d={d}")

    mpath = os.path.join(out_dir, "manifest.json")
    # merge with an existing manifest when building a subset
    if only and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old["variants"].update(manifest["variants"])
        old["kernels"]["speculate"].update(manifest["kernels"]["speculate"])
        old["kernels"]["verify"].update(manifest["kernels"]["verify"])
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    print(f"[aot] wrote {mpath}")

    from .golden import write_golden
    write_golden(out_dir, trained)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of variant names to (re)build")
    args = ap.parse_args()
    build(args.out_dir, only=args.only)


if __name__ == "__main__":
    main()
