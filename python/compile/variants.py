"""Model-variant registry: everything `train.py` / `aot.py` / the Rust
manifest loader need to agree on, in one place.

Variants (DESIGN.md §4 maps each to the paper's workload):
  gmm2d            quickstart toy target, K=100
  latent16         StableDiffusion-v2 stand-in (Fig 2 / Table 1 / Fig 3)
  pixel64          LSUN-Church pixel-model stand-in (Fig 4 / Table 2)
  policy_square    Robomimic Square stand-in (Fig 5 / Table 3)
  policy_transport Robomimic Transport stand-in
  policy_toolhang  Robomimic ToolHang stand-in
"""

import dataclasses
from typing import Optional

from .envs import TASKS, CHUNK
from .model import ModelConfig


@dataclasses.dataclass(frozen=True)
class Variant:
    name: str
    cfg: ModelConfig
    target: str              # gmm2d | latent16 | pixel64 | env
    env: Optional[str]       # task name for policy variants
    train_steps: int
    batch_size: int
    lr: float
    seed: int
    demos: int = 0           # expert episodes for policy variants


def _v(name, d, cond_dim, hidden, layers, k, target, env=None,
       train_steps=3000, batch_size=256, lr=1e-3, seed=0, demos=0):
    return Variant(name, ModelConfig(d=d, cond_dim=cond_dim, hidden=hidden,
                                     layers=layers, k_steps=k),
                   target, env, train_steps, batch_size, lr, seed, demos)


def _policy(name, task, hidden=384, layers=3, demos=1000, train_steps=16000):
    spec = TASKS[task]
    return _v(f"policy_{task}", d=CHUNK * spec.action_dim,
              cond_dim=spec.obs_dim, hidden=hidden, layers=layers, k=100,
              target="env", env=task, train_steps=train_steps,
              seed=hash(task) % (2**31), demos=demos)


VARIANTS = {v.name: v for v in [
    _v("gmm2d", d=2, cond_dim=0, hidden=128, layers=3, k=100,
       target="gmm2d", train_steps=3000, seed=7),
    _v("latent16", d=16, cond_dim=10, hidden=256, layers=4, k=1000,
       target="latent16", train_steps=4000, seed=11),
    _v("pixel64", d=64, cond_dim=0, hidden=128, layers=3, k=1000,
       target="pixel64", train_steps=4000, seed=13),
    _policy("square", "square"),
    _policy("transport", "transport"),
    _policy("toolhang", "toolhang"),
]}

# Batched denoise artifact sizes; the Rust runtime pads to the smallest
# B >= n and chunks batches larger than MAX_B across "workers".
BATCH_SIZES = [1, 2, 4, 8, 16, 32]
MAX_B = BATCH_SIZES[-1]

# Speculation-chain length per HLO speculate/verify kernel artifact.
SPEC_T = 32
